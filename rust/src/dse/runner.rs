//! Parallel sweep evaluation: grid points fan out over
//! [`crate::util::threadpool`], each driving the closed-form batch
//! simulator; results come back in grid order regardless of thread
//! count.

use crate::config::SimConfig;
use crate::mapping::{self, MappingScheme};
use crate::pruning::synthetic::generate_layer;
use crate::pruning::NetworkWeights;
use crate::sim;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::threadpool;
use crate::xbar::CellGeometry;

use super::{
    select_config, CacheEnv, FrontierSnapshot, Objective, ParetoFrontier,
    PointMetrics, PointResult, ResultCache, SweepPoint, SweepSpec,
    TunedConfig, Workload,
};

/// The exact [`SimConfig`] one sweep evaluation runs under: the
/// workload picks the trace mode (sampled `w.samples` positions, or
/// every position when `w.exact`) and the point contributes its
/// simulation-policy axes (zero-detection, block-switch cost). Also
/// part of the cache identity ([`super::ResultCache`]), so a change to
/// any simulation default — or a different trace mode / policy axis —
/// invalidates cached entries instead of silently serving metrics a
/// fresh evaluation would no longer reproduce.
pub fn effective_sim_config(w: &Workload, p: &SweepPoint) -> SimConfig {
    SimConfig {
        sample_positions: if w.exact { None } else { Some(w.samples) },
        seed: w.seed,
        zero_detection: p.zero_detection,
        block_switch_cycles: p.block_switch_cycles,
        ..Default::default()
    }
}

/// Evaluate one grid point: a pure function of `(workload, point)`.
///
/// Weight synthesis is seeded from the workload seed, the layer index
/// and the point's *compression* knobs only (pattern count, pruning
/// rate) — points that differ only in hardware geometry map and
/// simulate the exact same network, so their metrics are directly
/// comparable. The activation traces are seeded from the workload seed
/// alone, shared by every scheme (the same rule
/// [`sim::simulate_network_batch`] applies).
pub fn evaluate_point(w: &Workload, p: &SweepPoint) -> Result<PointMetrics, String> {
    let hw = p.hardware()?;
    let scheme: Box<dyn MappingScheme> = mapping::scheme_by_name(&p.scheme)
        .ok_or_else(|| format!("unknown mapping scheme '{}'", p.scheme))?;
    let geom = CellGeometry::from_hw(&hw);
    let spec = w.spec();

    let layers = spec
        .layers
        .iter()
        .enumerate()
        .map(|(li, l)| {
            let n_pat = p.n_patterns.clamp(1, l.cout * l.cin);
            let mut rng = Rng::seed_from(
                w.seed
                    ^ (li as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    ^ ((p.n_patterns as u64) << 17)
                    ^ p.pruning.to_bits().rotate_left(13),
            );
            generate_layer(l.cout, l.cin, n_pat, p.pruning, w.zero_ratio, &mut rng)
        })
        .collect();
    let nwts = NetworkWeights::new(spec.clone(), layers);

    // Inner work is single-threaded: the sweep parallelizes across
    // points, and nesting pools would only add scheduling noise.
    let mapped = scheme.map_network(&nwts, &geom, 1);
    let sim_cfg = effective_sim_config(w, p);
    let batch = sim::simulate_network_batch(
        &mapped,
        &spec,
        &hw,
        &sim_cfg,
        w.n_images.max(1),
        1,
    );

    let area_cells = (mapped.total_crossbars() * geom.cells_per_xbar()) as f64;
    // Multi-core points pipeline layers across cores: cycles become
    // the placement plan's batch makespan (transfer cost included).
    // `cores == 1` keeps the historical non-pipelined accumulation
    // untouched — bit for bit — rather than routing through a planner
    // that would sum the same numbers in a different order.
    let cycles = if hw.cores > 1 {
        let ipu = sim::scheme_has_ipu(&p.scheme) && p.zero_detection;
        let problem = sim::placement::PlacementProblem::from_batch(
            &batch, &spec, &hw, &sim_cfg, ipu,
        );
        sim::placement::plan(&problem).pipeline_makespan(batch.n_images())
    } else {
        batch.total_cycles()
    };
    Ok(PointMetrics {
        cycles,
        energy_pj: batch.total_energy().total_pj(),
        area_cells,
        crossbars: mapped.total_crossbars(),
        ou_ops: batch.total_ou_ops(),
        utilization: mapped.total_used_cells() as f64 / area_cells.max(1.0),
    })
}

/// The sequential stages of one sweep run, in execution order. The
/// runner reports stage boundaries through the
/// [`SweepRunner::run_observed`] callback; it never reads a clock
/// itself (the `dse` module is a wall-clock-free pure path — timing,
/// when wanted, is measured by the caller at the CLI boundary).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SweepStage {
    /// Grid expansion ([`SweepSpec::expand`]).
    Expand,
    /// Cache-environment construction ([`CacheEnv::for_sweep`]).
    Cache,
    /// The parallel point fan-out (cache load → evaluate → store).
    Evaluate,
    /// Pareto frontier extraction (full or warm-started).
    Frontier,
    /// Frontier snapshot persistence for future warm starts.
    Snapshot,
}

impl SweepStage {
    /// Every stage, in the order `run_observed` visits them.
    pub const ALL: [SweepStage; 5] = [
        SweepStage::Expand,
        SweepStage::Cache,
        SweepStage::Evaluate,
        SweepStage::Frontier,
        SweepStage::Snapshot,
    ];

    /// Stable lowercase stage name (profile JSON key).
    pub fn name(self) -> &'static str {
        match self {
            SweepStage::Expand => "expand",
            SweepStage::Cache => "cache",
            SweepStage::Evaluate => "evaluate",
            SweepStage::Frontier => "frontier",
            SweepStage::Snapshot => "snapshot",
        }
    }
}

/// A configured sweep, ready to run.
pub struct SweepRunner {
    pub spec: SweepSpec,
    /// Worker threads for the point fan-out (values < 1 clamp to 1).
    pub threads: usize,
    /// On-disk result cache; `None` disables caching entirely.
    pub cache: Option<ResultCache>,
}

impl SweepRunner {
    /// Run the sweep: expand the grid, evaluate every point (cache
    /// first), extract the frontier. Results are in grid order and
    /// independent of `threads`.
    pub fn run(&self) -> SweepOutcome {
        self.run_with(false)
    }

    /// [`SweepRunner::run`], optionally warm-starting the frontier
    /// extraction from the cache's stored [`FrontierSnapshot`].
    ///
    /// The cache identity environment (workload JSON, base hardware
    /// JSON, per-policy `SimConfig` JSON) is built **once** here and
    /// shared by every point's load/store — previously each of the up
    /// to `2 × n` cache calls re-serialized all three from scratch.
    ///
    /// With `warm_start`, the previous run's frontier snapshot seeds an
    /// incremental [`ParetoFrontier::update`] over only the points the
    /// snapshot had not covered. This is used only when the snapshot's
    /// covered set is a subset of the current grid (the grid only
    /// grew); otherwise — first run, changed workload, shrunk grid —
    /// it silently falls back to full extraction. Either path produces
    /// bit-identical members, so the frontier artifact does not depend
    /// on the flag.
    pub fn run_with(&self, warm_start: bool) -> SweepOutcome {
        self.run_observed(warm_start, &mut |_, _| {})
    }

    /// [`SweepRunner::run_with`] with a stage-boundary observer:
    /// `on_stage(stage, true)` fires when a stage begins and
    /// `on_stage(stage, false)` when it ends, always from the calling
    /// thread, always in [`SweepStage::ALL`] order, always strictly
    /// paired. The runner itself stays wall-clock-free — callers that
    /// want a timing profile (`rram-accel dse --profile`) read their
    /// own clock inside the callback.
    pub fn run_observed(
        &self,
        warm_start: bool,
        on_stage: &mut dyn FnMut(SweepStage, bool),
    ) -> SweepOutcome {
        on_stage(SweepStage::Expand, true);
        let points = self.spec.expand();
        on_stage(SweepStage::Expand, false);
        let w = &self.spec.workload;
        let cache = self.cache.as_ref();
        on_stage(SweepStage::Cache, true);
        let env = cache.map(|_| CacheEnv::for_sweep(w, &points));
        on_stage(SweepStage::Cache, false);
        on_stage(SweepStage::Evaluate, true);
        let results = threadpool::parallel_map_indexed(
            &points,
            self.threads.max(1),
            |i, p| {
                if let (Some(c), Some(env)) = (cache, env.as_ref()) {
                    if let Some(m) = c.load_with(env, w, p) {
                        return PointResult {
                            index: i,
                            point: p.clone(),
                            outcome: Ok(m),
                            cache_hit: true,
                        };
                    }
                }
                let outcome = evaluate_point(w, p);
                if let (Some(c), Some(env), Ok(m)) =
                    (cache, env.as_ref(), &outcome)
                {
                    if let Err(e) = c.store_with(env, w, p, m) {
                        eprintln!(
                            "[dse] cache write failed for {}: {e} \
                             (continuing uncached)",
                            p.label()
                        );
                    }
                }
                PointResult { index: i, point: p.clone(), outcome, cache_hit: false }
            },
        );
        on_stage(SweepStage::Evaluate, false);
        on_stage(SweepStage::Frontier, true);
        let frontier = match (warm_start, cache, env.as_ref()) {
            (true, Some(c), Some(env)) => warm_frontier(c, env, w, &results)
                .unwrap_or_else(|| ParetoFrontier::from_results(&results)),
            _ => ParetoFrontier::from_results(&results),
        };
        on_stage(SweepStage::Frontier, false);
        on_stage(SweepStage::Snapshot, true);
        if let (Some(c), Some(env)) = (cache, env.as_ref()) {
            let snap = FrontierSnapshot {
                covered: results
                    .iter()
                    .filter(|r| r.outcome.is_ok())
                    .map(|r| env.point_key(w, &r.point))
                    .collect(),
                members: frontier
                    .members
                    .iter()
                    .map(|&i| env.point_key(w, &results[i].point))
                    .collect(),
            };
            if let Err(e) = c.store_snapshot(env, &snap) {
                eprintln!("[dse] frontier snapshot write failed: {e}");
            }
        }
        on_stage(SweepStage::Snapshot, false);
        SweepOutcome { spec: self.spec.clone(), results, frontier }
    }
}

/// Seed the frontier from the cached snapshot and fold in only the
/// points the snapshot did not cover. `None` (→ full extraction) when
/// there is no snapshot or when any previously covered point left the
/// grid — a dominated point's dominator might have gone with it, so the
/// shortcut would not be sound.
fn warm_frontier(
    cache: &ResultCache,
    env: &CacheEnv,
    w: &Workload,
    results: &[PointResult],
) -> Option<ParetoFrontier> {
    let snap = cache.load_snapshot(env)?;
    let covered: std::collections::BTreeSet<u64> =
        snap.covered.iter().copied().collect();
    let member_keys: std::collections::BTreeSet<u64> =
        snap.members.iter().copied().collect();
    let mut members = Vec::new();
    let mut fresh = Vec::new();
    let mut grid_keys = std::collections::BTreeSet::new();
    for r in results.iter().filter(|r| r.outcome.is_ok()) {
        let k = env.point_key(w, &r.point);
        grid_keys.insert(k);
        if member_keys.contains(&k) {
            members.push(r.index);
        } else if !covered.contains(&k) {
            fresh.push(r.index);
        }
    }
    if !covered.iter().all(|k| grid_keys.contains(k)) {
        return None;
    }
    let mut frontier = ParetoFrontier { members };
    frontier.update(results, &fresh);
    Some(frontier)
}

/// Everything a finished sweep produced.
pub struct SweepOutcome {
    pub spec: SweepSpec,
    /// One result per grid point, in grid order.
    pub results: Vec<PointResult>,
    pub frontier: ParetoFrontier,
}

impl SweepOutcome {
    /// Points evaluated successfully (fresh or cached).
    pub fn evaluated(&self) -> usize {
        self.results.iter().filter(|r| r.outcome.is_ok()).count()
    }

    /// Points skipped as invalid (geometry rejected, unknown scheme).
    pub fn skipped(&self) -> usize {
        self.results.len() - self.evaluated()
    }

    pub fn cache_hits(&self) -> usize {
        self.results.iter().filter(|r| r.cache_hit).count()
    }

    /// Successful evaluations that were computed fresh this run.
    pub fn cache_misses(&self) -> usize {
        self.results
            .iter()
            .filter(|r| !r.cache_hit && r.outcome.is_ok())
            .count()
    }

    /// One-line run summary including the cache tally (stdout only —
    /// never part of the frontier artifact).
    pub fn summary_line(&self) -> String {
        format!(
            "swept {} points: {} evaluated, {} skipped, frontier {}; \
             cache: {} hits, {} misses",
            self.results.len(),
            self.evaluated(),
            self.skipped(),
            self.frontier.len(),
            self.cache_hits(),
            self.cache_misses(),
        )
    }

    /// The deterministic frontier artifact (see
    /// [`ParetoFrontier::to_json`]).
    pub fn frontier_json(&self) -> Json {
        self.frontier.to_json(&self.spec, &self.results)
    }

    pub fn frontier_csv(&self) -> String {
        self.frontier.to_csv(&self.results)
    }

    /// The frontier point a weighted objective selects.
    pub fn select(&self, obj: &Objective) -> Option<TunedConfig> {
        select_config(&self.results, &self.frontier, obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> SweepSpec {
        SweepSpec {
            grid: "tiny".into(),
            schemes: vec!["naive".into(), "pattern".into()],
            ou: vec![(9, 8)],
            xbar: vec![(256, 256)],
            patterns: vec![4],
            pruning: vec![0.8],
            zero_detection: vec![true],
            block_switch: vec![2.0],
            cores: vec![1],
            interconnect: vec![(32.0, 4.0)],
            workload: Workload {
                name: "t".into(),
                layers: vec![crate::nn::ConvLayer {
                    name: "c0".into(),
                    cin: 4,
                    cout: 16,
                    fmap: 4,
                }],
                n_images: 2,
                samples: 8,
                exact: false,
                zero_ratio: 0.25,
                seed: 11,
            },
        }
    }

    #[test]
    fn evaluate_point_is_deterministic_and_scheme_sensitive() {
        let spec = tiny_spec();
        let pts = spec.expand();
        assert_eq!(pts.len(), 2);
        let a1 = evaluate_point(&spec.workload, &pts[0]).unwrap();
        let a2 = evaluate_point(&spec.workload, &pts[0]).unwrap();
        assert_eq!(a1, a2, "pure function of (workload, point)");
        let b = evaluate_point(&spec.workload, &pts[1]).unwrap();
        // pattern mapping does strictly less work than naive on a
        // pruned layer
        assert!(b.cycles < a1.cycles, "{} vs {}", b.cycles, a1.cycles);
        assert!(b.energy_pj < a1.energy_pj);
        assert!(a1.cycles > 0.0 && a1.area_cells > 0.0);
        assert!(a1.utilization > 0.0 && a1.utilization <= 1.0);
    }

    #[test]
    fn runner_reports_skips_and_keeps_grid_order() {
        let mut spec = tiny_spec();
        // an OU taller than the crossbar is expanded but skipped
        spec.ou.push((1024, 8));
        let outcome = SweepRunner { spec, threads: 2, cache: None }.run();
        assert_eq!(outcome.results.len(), 4);
        assert_eq!(outcome.evaluated(), 2);
        assert_eq!(outcome.skipped(), 2);
        for (i, r) in outcome.results.iter().enumerate() {
            assert_eq!(r.index, i, "grid order preserved");
        }
        let bad: Vec<&PointResult> = outcome
            .results
            .iter()
            .filter(|r| r.outcome.is_err())
            .collect();
        assert_eq!(bad.len(), 2);
        assert!(bad[0].point.ou_rows == 1024);
        // frontier only ever references valid points
        for &i in &outcome.frontier.members {
            assert!(outcome.results[i].outcome.is_ok());
        }
        assert!(outcome.summary_line().contains("2 skipped"));
    }

    #[test]
    fn sim_policy_axes_and_exact_mode_reach_the_evaluation() {
        let spec = tiny_spec();
        let w = &spec.workload;
        let pts = spec.expand();
        assert_eq!(pts[1].scheme, "pattern");
        let on = evaluate_point(w, &pts[1]).unwrap();

        // Exact mode ignores `samples` entirely: the trace covers every
        // output position, so two exact workloads differing only in the
        // sample count evaluate bit-identically.
        let mut we = w.clone();
        we.exact = true;
        let exact = evaluate_point(&we, &pts[1]).unwrap();
        let mut we3 = we.clone();
        we3.samples = 3;
        assert_eq!(exact, evaluate_point(&we3, &pts[1]).unwrap());
        assert!(exact.ou_ops > 0.0 && exact.cycles > 0.0);

        // Zero-detection off can only execute more OU operations.
        let mut p_off = pts[1].clone();
        p_off.zero_detection = false;
        let off = evaluate_point(w, &p_off).unwrap();
        assert!(off.ou_ops >= on.ou_ops, "{} < {}", off.ou_ops, on.ou_ops);

        // Block-switch cost changes cycles only, never the OU schedule.
        let mut p_bs = pts[1].clone();
        p_bs.block_switch_cycles = 50.0;
        let bs = evaluate_point(w, &p_bs).unwrap();
        assert_eq!(bs.ou_ops, on.ou_ops);
        assert!(bs.cycles >= on.cycles);
    }

    #[test]
    fn run_observed_emits_paired_stages_in_fixed_order() {
        let runner = SweepRunner { spec: tiny_spec(), threads: 2, cache: None };
        let mut events: Vec<(SweepStage, bool)> = Vec::new();
        let observed =
            runner.run_observed(false, &mut |s, begin| events.push((s, begin)));
        // begin/end strictly paired, in SweepStage::ALL order
        assert_eq!(events.len(), 2 * SweepStage::ALL.len());
        for (i, stage) in SweepStage::ALL.iter().enumerate() {
            assert_eq!(events[2 * i], (*stage, true), "{events:?}");
            assert_eq!(events[2 * i + 1], (*stage, false), "{events:?}");
        }
        // the observer changes nothing about the outcome
        let plain = runner.run_with(false);
        assert_eq!(observed.frontier.members, plain.frontier.members);
        assert_eq!(observed.evaluated(), plain.evaluated());
        assert_eq!(SweepStage::Evaluate.name(), "evaluate");
    }

    #[test]
    fn unknown_scheme_is_a_skip_not_a_panic() {
        let w = Workload::small(3);
        let p = SweepPoint {
            scheme: "definitely-not-a-scheme".into(),
            ou_rows: 9,
            ou_cols: 8,
            xbar_rows: 512,
            xbar_cols: 512,
            n_patterns: 4,
            pruning: 0.8,
            zero_detection: true,
            block_switch_cycles: 2.0,
            cores: 1,
            noc_bandwidth: 32.0,
            noc_hop_latency: 4.0,
        };
        let e = evaluate_point(&w, &p).unwrap_err();
        assert!(e.contains("unknown mapping scheme"), "{e}");
    }

    #[test]
    fn multicore_point_pipelines_the_batch() {
        let w = Workload::small(7);
        let base = SweepPoint {
            scheme: "pattern".into(),
            ou_rows: 9,
            ou_cols: 8,
            xbar_rows: 512,
            xbar_cols: 512,
            n_patterns: 4,
            pruning: 0.8,
            zero_detection: true,
            block_switch_cycles: 2.0,
            cores: 1,
            noc_bandwidth: 32.0,
            noc_hop_latency: 4.0,
        };
        let single = evaluate_point(&w, &base).unwrap();

        // A fast interconnect lets the pipeline beat one core; area and
        // energy are placement-invariant.
        let mut fast = base.clone();
        fast.cores = 2;
        fast.noc_bandwidth = 1e9;
        fast.noc_hop_latency = 0.0;
        let multi = evaluate_point(&w, &fast).unwrap();
        assert!(
            multi.cycles < single.cycles,
            "{} vs {}",
            multi.cycles,
            single.cycles
        );
        assert_eq!(multi.energy_pj, single.energy_pj);
        assert_eq!(multi.area_cells, single.area_cells);
        assert_eq!(multi.ou_ops, single.ou_ops);

        // A crippled interconnect makes the planner keep everything on
        // one core — the makespan degenerates to the non-pipelined
        // total (same numbers, possibly reassociated).
        let mut slow = base.clone();
        slow.cores = 2;
        slow.noc_bandwidth = 1e-6;
        slow.noc_hop_latency = 1e12;
        let bad = evaluate_point(&w, &slow).unwrap();
        let rel = (bad.cycles - single.cycles).abs() / single.cycles;
        assert!(rel < 1e-9, "{} vs {}", bad.cycles, single.cycles);

        // determinism: multi-core evaluation is still a pure function
        assert_eq!(multi, evaluate_point(&w, &fast).unwrap());
    }
}
