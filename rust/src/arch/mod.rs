//! Accelerator architecture units (paper §IV, Fig. 6).
//!
//! The dataflow is: input register → **Input Preprocessing Unit** (select
//! the activations each pattern needs; detect all-zero inputs) → DACs →
//! RRAM crossbar OUs → ADCs → shift-add → **Output Indexing Unit**
//! (reorder out-of-sequence bitline outputs using the weight index
//! buffer) → output register. The cycle/energy simulator ([`crate::sim`])
//! and the functional simulator drive these units directly.

use crate::mapping::PatternBlock;

/// Input Preprocessing Unit (paper §IV-A).
///
/// Holds one im2col row (the receptive-field window of one output
/// position) and serves pattern-selected slices of it to the crossbar
/// wordlines, plus the all-zero detection that gates useless OU work.
#[derive(Debug, Clone)]
pub struct InputPreprocessor<'a> {
    /// im2col row, length `cin * 9`, ordering as `nn::im2col`.
    row: &'a [f32],
}

impl<'a> InputPreprocessor<'a> {
    pub fn new(row: &'a [f32]) -> InputPreprocessor<'a> {
        InputPreprocessor { row }
    }

    /// Select the inputs a pattern block's wordlines need (paper: "we
    /// only send the input activations corresponding to the nonzero
    /// weights").
    pub fn select(&self, block: &PatternBlock) -> Vec<f32> {
        block
            .input_rows()
            .into_iter()
            .map(|r| self.row[r])
            .collect()
    }

    /// All-zero detection (paper §IV-A): true when every input the block
    /// would consume is zero, so the whole block's OUs can be skipped.
    pub fn all_zero(&self, block: &PatternBlock) -> bool {
        block.input_rows().into_iter().all(|r| self.row[r] == 0.0)
    }
}

/// Output Indexing Unit (paper §IV-B).
///
/// Accumulates out-of-sequence bitline results into the correct output
/// channel addresses using the index buffer's out-channel indexes.
#[derive(Debug, Clone)]
pub struct OutputIndexer {
    out: Vec<f32>,
}

impl OutputIndexer {
    pub fn new(cout: usize) -> OutputIndexer {
        OutputIndexer { out: vec![0.0; cout] }
    }

    /// Scatter one block's column results (`values[k]` = column `k` of
    /// the block) into their true output channels.
    pub fn scatter(&mut self, block: &PatternBlock, values: &[f32]) {
        debug_assert_eq!(values.len(), block.kernels());
        for (v, &oc) in values.iter().zip(block.out_channels.iter()) {
            self.out[oc as usize] += v;
        }
    }

    pub fn finish(self) -> Vec<f32> {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::Pattern;

    fn block(cin: usize, pattern: u16, outs: &[u32]) -> PatternBlock {
        let p = Pattern(pattern);
        PatternBlock {
            cin,
            pattern: p,
            out_channels: outs.to_vec(),
            weights: vec![1.0; p.size() * outs.len()],
        }
    }

    #[test]
    fn preprocessor_selects_pattern_inputs() {
        // two channels; row = [0..18)
        let row: Vec<f32> = (0..18).map(|i| i as f32).collect();
        let ipp = InputPreprocessor::new(&row);
        let b = block(1, 0b100000101, &[0]); // positions 0, 2, 8 of ch 1
        assert_eq!(ipp.select(&b), vec![9.0, 11.0, 17.0]);
    }

    #[test]
    fn all_zero_detection() {
        let mut row = vec![1.0f32; 18];
        row[9] = 0.0;
        row[11] = 0.0;
        row[17] = 0.0;
        let ipp = InputPreprocessor::new(&row);
        let b = block(1, 0b100000101, &[0]);
        assert!(ipp.all_zero(&b)); // its three inputs are all zero
        let b2 = block(1, 0b100000111, &[0]); // adds position 1 (= 1.0)
        assert!(!ipp.all_zero(&b2));
        let b3 = block(0, 0b100000101, &[0]); // channel 0 is nonzero
        assert!(!ipp.all_zero(&b3));
    }

    #[test]
    fn indexer_scatters_and_accumulates() {
        let mut oi = OutputIndexer::new(5);
        let b1 = block(0, 0b1, &[3, 1]);
        let b2 = block(1, 0b1, &[3]);
        oi.scatter(&b1, &[0.5, 2.0]);
        oi.scatter(&b2, &[1.5]);
        let out = oi.finish();
        assert_eq!(out, vec![0.0, 2.0, 0.0, 2.0, 0.0]);
    }
}
