//! Weight index buffer (paper §III-B storage, §IV-C decode, §V-D cost).
//!
//! Because kernels are reordered, the accelerator must store, "pattern by
//! pattern in the same order as mapping the pattern blocks to the
//! crossbar", (a) the pattern shape (9-bit mask, which encodes the size)
//! and (b) the output-channel index of every stored kernel. §IV-C shows
//! the weights' *placement* is recoverable from just this sequence plus
//! the crossbar geometry — the decoder here replays the Fig. 5 placement
//! walk, which the round-trip tests pin against the actual placements.
//!
//! Overhead model (§V-D): one `ceil(log2(cout))`-bit (≤ 9 for 512
//! channels) index per stored kernel; all-zero-pattern kernels are never
//! stored, so their indexes are saved too. Pattern shapes cost 9 + 16
//! bits per block ("this overhead can be ignored" — we count it anyway).

use super::placement::place_blocks;
use super::{MappedLayer, Placement};
use crate::pruning::Pattern;
use crate::xbar::CellGeometry;

/// Bit-packed writer (MSB-first within each byte).
#[derive(Debug, Default)]
pub struct BitWriter {
    bytes: Vec<u8>,
    bit: usize,
}

impl BitWriter {
    pub fn new() -> BitWriter {
        BitWriter::default()
    }

    pub fn push(&mut self, value: u32, bits: usize) {
        debug_assert!(bits <= 32);
        debug_assert!(bits == 32 || value < (1u32 << bits));
        for i in (0..bits).rev() {
            let b = (value >> i) & 1;
            if self.bit == 0 {
                self.bytes.push(0);
            }
            let last = self.bytes.len() - 1;
            self.bytes[last] |= (b as u8) << (7 - self.bit);
            self.bit = (self.bit + 1) % 8;
        }
    }

    pub fn bit_len(&self) -> usize {
        if self.bytes.is_empty() {
            0
        } else {
            (self.bytes.len() - 1) * 8 + if self.bit == 0 { 8 } else { self.bit }
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.bytes
    }
}

/// Bit-packed reader matching [`BitWriter`].
#[derive(Debug)]
pub struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(bytes: &'a [u8]) -> BitReader<'a> {
        BitReader { bytes, pos: 0 }
    }

    pub fn read(&mut self, bits: usize) -> Option<u32> {
        let mut v = 0u32;
        for _ in 0..bits {
            let byte = self.bytes.get(self.pos / 8)?;
            let b = (byte >> (7 - self.pos % 8)) & 1;
            v = (v << 1) | b as u32;
            self.pos += 1;
        }
        Some(v)
    }
}

/// Bits needed for an output-channel index ("no more than 9 bits for
/// 512 output channels").
pub fn index_bits(cout: usize) -> usize {
    (usize::BITS - (cout.max(2) - 1).leading_zeros()) as usize
}

/// Encoded index buffer of one mapped layer.
#[derive(Debug, Clone, PartialEq)]
pub struct IndexBuffer {
    pub bytes: Vec<u8>,
    pub n_blocks: usize,
    pub cout: usize,
    pub cin: usize,
}

/// Serialize a mapped layer's index stream (block placement order):
/// per block `[pattern mask: 9][cin: 16][kernel count: 16]`, then
/// `index_bits(cout)` bits per kernel.
pub fn encode(layer: &MappedLayer) -> IndexBuffer {
    let kbits = index_bits(layer.cout);
    let mut w = BitWriter::new();
    for b in &layer.blocks {
        w.push(b.pattern.0 as u32, 9);
        w.push(b.cin as u32, 16);
        w.push(b.kernels() as u32, 16);
        for &oc in &b.out_channels {
            w.push(oc, kbits);
        }
    }
    IndexBuffer {
        bytes: w.into_bytes(),
        n_blocks: layer.blocks.len(),
        cout: layer.cout,
        cin: layer.cin,
    }
}

/// One decoded index-buffer entry.
#[derive(Debug, Clone, PartialEq)]
pub struct DecodedBlock {
    pub pattern: Pattern,
    pub cin: usize,
    pub out_channels: Vec<u32>,
}

/// Parse the index stream back into block descriptors.
pub fn decode(buf: &IndexBuffer) -> Result<Vec<DecodedBlock>, String> {
    let kbits = index_bits(buf.cout);
    let mut r = BitReader::new(&buf.bytes);
    let mut out = Vec::with_capacity(buf.n_blocks);
    for i in 0..buf.n_blocks {
        let pat = r.read(9).ok_or(format!("truncated at block {i}"))?;
        let cin = r.read(16).ok_or("truncated cin")? as usize;
        let count = r.read(16).ok_or("truncated count")? as usize;
        let mut ocs = Vec::with_capacity(count);
        for _ in 0..count {
            ocs.push(r.read(kbits).ok_or("truncated kernel index")?);
        }
        out.push(DecodedBlock {
            pattern: Pattern(pat as u16),
            cin,
            out_channels: ocs,
        });
    }
    Ok(out)
}

/// §IV-C: reconstruct every block's placement from the decoded index
/// stream alone (pattern size + kernel count) by replaying the Fig. 5
/// placement walk.
pub fn reconstruct_placements(
    blocks: &[DecodedBlock],
    geom: &CellGeometry,
) -> Vec<Placement> {
    let extents: Vec<(usize, usize)> = blocks
        .iter()
        .map(|b| (b.pattern.size(), geom.weight_cols(b.out_channels.len())))
        .collect();
    place_blocks(&extents, geom).placements
}

/// §V-D index overhead of a mapped layer, in bits: per-kernel indexes
/// plus per-block shape descriptors.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IndexOverhead {
    pub kernel_index_bits: usize,
    pub shape_bits: usize,
}

impl IndexOverhead {
    pub fn total_bits(&self) -> usize {
        self.kernel_index_bits + self.shape_bits
    }

    pub fn total_kib(&self) -> f64 {
        self.total_bits() as f64 / 8.0 / 1024.0
    }
}

/// Compute §V-D overhead for one mapped layer. The paper counts 9 bits
/// per stored kernel; we use `index_bits(cout)` (≤ 9) which matches at
/// 512 channels.
pub fn overhead(layer: &MappedLayer) -> IndexOverhead {
    let kbits = index_bits(layer.cout);
    let stored: usize = layer.blocks.iter().map(|b| b.kernels()).sum();
    IndexOverhead {
        kernel_index_bits: stored * kbits,
        shape_bits: layer.blocks.len() * (9 + 16 + 16),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::mapping::pattern::PatternMapping;
    use crate::mapping::MappingScheme;
    use crate::nn::ConvLayer;
    use crate::pruning::synthetic::generate_layer;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn geom() -> CellGeometry {
        CellGeometry::from_hw(&HardwareConfig::default())
    }

    #[test]
    fn bit_roundtrip() {
        let mut w = BitWriter::new();
        w.push(0b101, 3);
        w.push(0xFFFF, 16);
        w.push(0, 1);
        w.push(511, 9);
        assert_eq!(w.bit_len(), 29);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read(3), Some(0b101));
        assert_eq!(r.read(16), Some(0xFFFF));
        assert_eq!(r.read(1), Some(0));
        assert_eq!(r.read(9), Some(511));
        // padding bits readable as zero, then EOF
        assert_eq!(r.read(3), Some(0));
        assert_eq!(r.read(8), None);
    }

    #[test]
    fn index_bits_paper_claim() {
        assert_eq!(index_bits(512), 9); // "no more than 9 bits"
        assert_eq!(index_bits(256), 8);
        assert_eq!(index_bits(64), 6);
        assert_eq!(index_bits(2), 1);
        assert_eq!(index_bits(1), 1);
        assert_eq!(index_bits(513), 10);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let mut rng = Rng::seed_from(4);
        let w = generate_layer(64, 8, 6, 0.84, 0.35, &mut rng);
        let l = ConvLayer { name: "t".into(), cout: 64, cin: 8, fmap: 8 };
        let ml = PatternMapping.map_layer(0, &l, &w, &geom());
        let buf = encode(&ml);
        let blocks = decode(&buf).unwrap();
        assert_eq!(blocks.len(), ml.blocks.len());
        for (d, b) in blocks.iter().zip(ml.blocks.iter()) {
            assert_eq!(d.pattern, b.pattern);
            assert_eq!(d.cin, b.cin);
            assert_eq!(d.out_channels, b.out_channels);
        }
    }

    #[test]
    fn placement_reconstruction_matches_mapper() {
        // the paper's §IV-C claim: indexes alone recover the placement
        let mut rng = Rng::seed_from(5);
        let w = generate_layer(96, 12, 8, 0.86, 0.4, &mut rng);
        let l = ConvLayer { name: "t".into(), cout: 96, cin: 12, fmap: 8 };
        let ml = PatternMapping.map_layer(0, &l, &w, &geom());
        let decoded = decode(&encode(&ml)).unwrap();
        let placements = reconstruct_placements(&decoded, &geom());
        assert_eq!(placements, ml.placements);
    }

    #[test]
    fn overhead_counts() {
        let mut rng = Rng::seed_from(6);
        let w = generate_layer(512, 2, 5, 0.85, 0.4, &mut rng);
        let l = ConvLayer { name: "t".into(), cout: 512, cin: 2, fmap: 8 };
        let ml = PatternMapping.map_layer(0, &l, &w, &geom());
        let stored: usize = ml.blocks.iter().map(|b| b.kernels()).sum();
        let oh = overhead(&ml);
        assert_eq!(oh.kernel_index_bits, stored * 9);
        assert_eq!(oh.shape_bits, ml.blocks.len() * 41);
        assert!(oh.total_kib() > 0.0);
        // deleted all-zero kernels don't pay for indexes
        assert!(stored < 1024);
    }

    #[test]
    fn prop_index_roundtrip() {
        prop::check("index roundtrip", 24, |rng: &mut Rng| {
            let cout = rng.range(1, 80);
            let cin = rng.range(1, 6);
            let n_pat = rng.range(1, 9).min(cout * cin);
            let w = generate_layer(cout, cin, n_pat, 0.75, 0.3, rng);
            let l = ConvLayer { name: "t".into(), cout, cin, fmap: 4 };
            let ml = PatternMapping.map_layer(0, &l, &w, &geom());
            let decoded = decode(&encode(&ml)).unwrap();
            let placements = reconstruct_placements(&decoded, &geom());
            assert_eq!(placements, ml.placements, "placement reconstruction");
        });
    }
}
