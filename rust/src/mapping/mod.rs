//! Weight mapping schemes (the paper's core contribution and baselines).
//!
//! A mapped layer is a list of [`PatternBlock`]s with [`Placement`]s on
//! crossbar arrays. Every scheme (naive Fig. 1 baseline, the paper's
//! kernel-reordering pattern scheme §III, the k-means baseline [15] and
//! the SRE-style OU row-compression baseline [12]) lowers to this same
//! representation, so OU enumeration, energy accounting and the
//! functional simulator are shared.

pub mod index;
pub mod kmeans;
pub mod naive;
pub mod ou;
pub mod ou_sparse;
pub mod pattern;
pub mod placement;

use crate::nn::{ConvLayer, Tensor};
use crate::pruning::{NetworkWeights, Pattern};
use crate::util::threadpool;
use crate::xbar::CellGeometry;

/// Every registered scheme name, in the order [`scheme_by_name`]
/// resolves them (the DSE sweep axes and the CLI both draw from this).
pub const SCHEME_NAMES: [&str; 6] = [
    "naive",
    "pattern",
    "kmeans",
    "ou_sparse",
    "pattern-widthsort",
    "pattern-sizeorder",
];

/// Resolve a scheme by CLI / sweep-axis name. The single registry
/// shared by `rram-accel`, the DSE engine and `serve --auto-tune`.
pub fn scheme_by_name(name: &str) -> Option<Box<dyn MappingScheme>> {
    use pattern::{BlockOrder, PatternMapping, PatternMappingOrdered};
    match name {
        "naive" => Some(Box::new(naive::NaiveMapping)),
        "pattern" => Some(Box::new(PatternMapping)),
        "kmeans" => Some(Box::new(kmeans::KmeansMapping::default())),
        "ou_sparse" => Some(Box::new(ou_sparse::OuSparseMapping)),
        "pattern-widthsort" => {
            Some(Box::new(PatternMappingOrdered(BlockOrder::SizeThenWidth)))
        }
        "pattern-sizeorder" => {
            Some(Box::new(PatternMappingOrdered(BlockOrder::SizeThenChannel)))
        }
        _ => None,
    }
}

/// One pattern block: the kernels of input channel `cin` sharing
/// `pattern`, compressed to `pattern.size()` rows × `out_channels.len()`
/// weight columns (paper Fig. 4).
#[derive(Debug, Clone, PartialEq)]
pub struct PatternBlock {
    pub cin: usize,
    pub pattern: Pattern,
    /// Output channel of each kernel column, in stored order.
    pub out_channels: Vec<u32>,
    /// Compressed weights, row-major `[pattern.size()][out_channels.len()]`.
    pub weights: Vec<f32>,
}

impl PatternBlock {
    pub fn rows(&self) -> usize {
        self.pattern.size()
    }

    pub fn kernels(&self) -> usize {
        self.out_channels.len()
    }

    #[inline]
    pub fn weight(&self, row: usize, kernel: usize) -> f32 {
        self.weights[row * self.kernels() + kernel]
    }

    /// im2col row indices this block's wordlines consume
    /// (`cin * 9 + position` for each pattern position, ascending).
    pub fn input_rows(&self) -> Vec<usize> {
        self.pattern
            .positions()
            .into_iter()
            .map(|p| self.cin * 9 + p)
            .collect()
    }
}

/// Where a block landed: crossbar id + top-left cell + extent in cells.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    pub xbar: usize,
    pub row: usize,
    pub col: usize,
    /// Rows used (== block pattern size).
    pub rows: usize,
    /// Physical columns used (== kernels × cells_per_weight).
    pub cols: usize,
}

/// A conv layer mapped onto crossbars.
#[derive(Debug, Clone)]
pub struct MappedLayer {
    pub layer_idx: usize,
    pub cout: usize,
    pub cin: usize,
    pub geom: CellGeometry,
    pub blocks: Vec<PatternBlock>,
    /// Parallel to `blocks`.
    pub placements: Vec<Placement>,
    pub n_crossbars: usize,
    /// Cells actually storing weights.
    pub used_cells: usize,
    /// Kernels deleted because their pattern was all-zero.
    pub zero_kernels: usize,
}

impl MappedLayer {
    pub fn total_cells(&self) -> usize {
        self.n_crossbars * self.geom.xbar_rows * self.geom.xbar_cols
    }

    pub fn utilization(&self) -> f64 {
        if self.n_crossbars == 0 {
            return 0.0;
        }
        self.used_cells as f64 / self.total_cells() as f64
    }

    /// OU operations per input vector (one output position), without
    /// input skipping.
    pub fn ou_ops_per_position(&self) -> usize {
        self.blocks
            .iter()
            .map(|b| {
                self.geom
                    .ou_ops_for_block(b.rows(), self.geom.weight_cols(b.kernels()))
            })
            .sum()
    }

    /// Sanity invariants: placements in bounds, no overlaps, one
    /// placement per block with matching extents.
    pub fn validate(&self) -> Result<(), String> {
        if self.blocks.len() != self.placements.len() {
            return Err("blocks/placements length mismatch".into());
        }
        for (b, p) in self.blocks.iter().zip(self.placements.iter()) {
            if p.rows != b.rows() || p.cols != self.geom.weight_cols(b.kernels()) {
                return Err(format!("extent mismatch for block {b:?}"));
            }
            if p.row + p.rows > self.geom.xbar_rows
                || p.col + p.cols > self.geom.xbar_cols
            {
                return Err(format!("placement out of bounds: {p:?}"));
            }
            if p.xbar >= self.n_crossbars {
                return Err(format!("crossbar id out of range: {p:?}"));
            }
        }
        // overlap check via per-crossbar occupancy grids
        let cells = self.geom.xbar_rows * self.geom.xbar_cols;
        let mut grids = vec![vec![false; cells]; self.n_crossbars];
        for p in &self.placements {
            for r in p.row..p.row + p.rows {
                for c in p.col..p.col + p.cols {
                    let idx = r * self.geom.xbar_cols + c;
                    if grids[p.xbar][idx] {
                        return Err(format!("overlap at xbar {} ({r},{c})", p.xbar));
                    }
                    grids[p.xbar][idx] = true;
                }
            }
        }
        Ok(())
    }
}

/// A fully mapped network plus scheme-level aggregates.
#[derive(Debug, Clone)]
pub struct MappedNetwork {
    pub scheme: String,
    pub network: String,
    pub layers: Vec<MappedLayer>,
}

impl MappedNetwork {
    pub fn total_crossbars(&self) -> usize {
        self.layers.iter().map(|l| l.n_crossbars).sum()
    }

    pub fn total_used_cells(&self) -> usize {
        self.layers.iter().map(|l| l.used_cells).sum()
    }

    pub fn validate(&self) -> Result<(), String> {
        for (i, l) in self.layers.iter().enumerate() {
            l.validate().map_err(|e| format!("layer {i}: {e}"))?;
        }
        Ok(())
    }
}

/// A weight-mapping scheme: maps one conv layer's weights to crossbars.
pub trait MappingScheme: Sync {
    fn name(&self) -> &'static str;

    fn map_layer(
        &self,
        layer_idx: usize,
        layer: &ConvLayer,
        weights: &Tensor,
        geom: &CellGeometry,
    ) -> MappedLayer;

    /// Map a whole network (layers in parallel).
    fn map_network(
        &self,
        nw: &NetworkWeights,
        geom: &CellGeometry,
        threads: usize,
    ) -> MappedNetwork {
        let items: Vec<(usize, &ConvLayer, &Tensor)> = nw
            .spec
            .layers
            .iter()
            .zip(nw.layers.iter())
            .enumerate()
            .map(|(i, (l, w))| (i, l, w))
            .collect();
        let layers = threadpool::parallel_map(&items, threads, |(i, l, w)| {
            self.map_layer(*i, l, w, geom)
        });
        MappedNetwork {
            scheme: self.name().to_string(),
            network: nw.spec.name.clone(),
            layers,
        }
    }
}

/// Reconstruct the dense `[cout, cin, 3, 3]` weights from a mapped
/// layer (inverse of the compression — used by equivalence tests).
pub fn reconstruct_dense(layer: &MappedLayer) -> Tensor {
    let mut w = Tensor::zeros(&[layer.cout, layer.cin, 3, 3]);
    for b in &layer.blocks {
        let positions = b.pattern.positions();
        for (ki, &oc) in b.out_channels.iter().enumerate() {
            for (ri, &pos) in positions.iter().enumerate() {
                let v = b.weight(ri, ki);
                let idx = w.idx4(oc as usize, b.cin, pos / 3, pos % 3);
                // Schemes may store explicit zeros (naive); sum is safe
                // because each (oc, cin, pos) cell appears at most once.
                w.data[idx] += v;
            }
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;

    fn geom() -> CellGeometry {
        CellGeometry::from_hw(&HardwareConfig::default())
    }

    #[test]
    fn scheme_registry_resolves_every_name() {
        for name in SCHEME_NAMES {
            let s = scheme_by_name(name).unwrap_or_else(|| panic!("{name}"));
            assert!(!s.name().is_empty());
        }
        assert!(scheme_by_name("bogus").is_none());
    }

    #[test]
    fn block_accessors() {
        let b = PatternBlock {
            cin: 2,
            pattern: Pattern(0b000010011), // positions 0, 1, 4
            out_channels: vec![3, 7],
            weights: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        };
        assert_eq!(b.rows(), 3);
        assert_eq!(b.kernels(), 2);
        assert_eq!(b.weight(0, 1), 2.0);
        assert_eq!(b.weight(2, 0), 5.0);
        assert_eq!(b.input_rows(), vec![18, 19, 22]);
    }

    #[test]
    fn validate_catches_overlap() {
        let g = geom();
        let b = PatternBlock {
            cin: 0,
            pattern: Pattern(0b11),
            out_channels: vec![0],
            weights: vec![1.0, 2.0],
        };
        let p = Placement { xbar: 0, row: 0, col: 0, rows: 2, cols: 4 };
        let ml = MappedLayer {
            layer_idx: 0,
            cout: 1,
            cin: 1,
            geom: g,
            blocks: vec![b.clone(), b],
            placements: vec![p, p], // identical -> overlap
            n_crossbars: 1,
            used_cells: 16,
            zero_kernels: 0,
        };
        assert!(ml.validate().is_err());
    }

    #[test]
    fn reconstruct_roundtrip_simple() {
        let g = geom();
        let b = PatternBlock {
            cin: 1,
            pattern: Pattern(0b100000001), // pos 0 and 8
            out_channels: vec![2, 0],
            weights: vec![1.5, 2.5, -1.0, -2.0],
        };
        let ml = MappedLayer {
            layer_idx: 0,
            cout: 3,
            cin: 2,
            geom: g,
            blocks: vec![b],
            placements: vec![Placement { xbar: 0, row: 0, col: 0, rows: 2, cols: 8 }],
            n_crossbars: 1,
            used_cells: 16,
            zero_kernels: 0,
        };
        let w = reconstruct_dense(&ml);
        assert_eq!(w.at4(2, 1, 0, 0), 1.5);
        assert_eq!(w.at4(0, 1, 0, 0), 2.5);
        assert_eq!(w.at4(2, 1, 2, 2), -1.0);
        assert_eq!(w.at4(0, 1, 2, 2), -2.0);
        assert_eq!(w.at4(1, 0, 1, 1), 0.0);
    }
}
