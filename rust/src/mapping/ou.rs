//! Operation Unit organization (paper §IV-C, Fig. 5c).
//!
//! Every OU activation must lie inside one pattern block: different
//! patterns put different inputs on the same wordline, so they can never
//! be activated together. This module statically enumerates the OU
//! schedule of a mapped layer — the red boxes of Fig. 5c — which both
//! the cycle/energy simulator and the functional simulator execute.

use super::MappedLayer;
use crate::xbar::CellGeometry;

/// One scheduled OU activation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OuTask {
    /// Index of the owning pattern block within the layer.
    pub block: usize,
    /// Crossbar the OU fires on.
    pub xbar: usize,
    /// Row offset *within the block* (0, ou_rows, 2*ou_rows, ...).
    pub row_off: usize,
    /// Active rows (<= ou_rows; == block rows for single-group blocks).
    pub rows: usize,
    /// Column offset within the block, in cells.
    pub col_off: usize,
    /// Active columns in cells (<= ou_cols).
    pub cols: usize,
}

/// Enumerate the OU schedule of a mapped layer, block-major (the order
/// the control unit walks the index buffer).
pub fn enumerate_ous(layer: &MappedLayer) -> Vec<OuTask> {
    let geom = &layer.geom;
    let mut out = Vec::new();
    for (bi, (block, place)) in layer
        .blocks
        .iter()
        .zip(layer.placements.iter())
        .enumerate()
    {
        let h = block.rows();
        let w_cells = geom.weight_cols(block.kernels());
        debug_assert_eq!(place.rows, h);
        debug_assert_eq!(place.cols, w_cells);
        let mut row_off = 0;
        while row_off < h {
            let rows = (h - row_off).min(geom.ou_rows);
            let mut col_off = 0;
            while col_off < w_cells {
                let cols = (w_cells - col_off).min(geom.ou_cols);
                out.push(OuTask {
                    block: bi,
                    xbar: place.xbar,
                    row_off,
                    rows,
                    col_off,
                    cols,
                });
                col_off += cols;
            }
            row_off += rows;
        }
    }
    out
}

/// Check the §IV-C constraint set on a schedule.
pub fn validate_schedule(
    layer: &MappedLayer,
    tasks: &[OuTask],
    geom: &CellGeometry,
) -> Result<(), String> {
    let mut covered = vec![0usize; layer.blocks.len()];
    for t in tasks {
        let block = layer
            .blocks
            .get(t.block)
            .ok_or_else(|| format!("task {t:?}: bad block"))?;
        if t.rows == 0 || t.cols == 0 {
            return Err(format!("task {t:?}: empty OU"));
        }
        if t.rows > geom.ou_rows || t.cols > geom.ou_cols {
            return Err(format!("task {t:?}: exceeds OU size"));
        }
        // strictly inside one pattern block
        let h = block.rows();
        let w = geom.weight_cols(block.kernels());
        if t.row_off + t.rows > h || t.col_off + t.cols > w {
            return Err(format!("task {t:?}: leaves its pattern block"));
        }
        covered[t.block] += t.rows * t.cols;
    }
    // full coverage, no double-coverage
    for (bi, block) in layer.blocks.iter().enumerate() {
        let want = block.rows() * geom.weight_cols(block.kernels());
        if covered[bi] != want {
            return Err(format!(
                "block {bi}: covered {} of {want} cells",
                covered[bi]
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::mapping::pattern::PatternMapping;
    use crate::mapping::MappingScheme;
    use crate::nn::ConvLayer;
    use crate::pruning::synthetic::generate_layer;
    use crate::util::prop;
    use crate::util::rng::Rng;
    use crate::xbar::CellGeometry;

    fn geom() -> CellGeometry {
        CellGeometry::from_hw(&HardwareConfig::default())
    }

    #[test]
    fn fig5c_ou_boxes() {
        // A 3-row x 20-kernel block with cpw=1, OU 4x4 -> 1 row-group x
        // 5 col-groups.
        let g = CellGeometry {
            cells_per_weight: 1,
            ou_rows: 4,
            ou_cols: 4,
            ..geom()
        };
        let mut rng = Rng::seed_from(1);
        let w = generate_layer(20, 1, 1, 1.0 - 3.0 / 9.0, 0.0, &mut rng);
        let l = ConvLayer { name: "t".into(), cout: 20, cin: 1, fmap: 4 };
        let ml = PatternMapping.map_layer(0, &l, &w, &g);
        assert_eq!(ml.blocks.len(), 1);
        assert_eq!(ml.blocks[0].rows(), 3);
        let tasks = enumerate_ous(&ml);
        assert_eq!(tasks.len(), 5);
        assert!(tasks.iter().all(|t| t.rows == 3));
        assert_eq!(tasks[4].cols, 4);
        validate_schedule(&ml, &tasks, &g).unwrap();
    }

    #[test]
    fn tall_block_multiple_row_groups() {
        // OU 4 rows; a FULL pattern (9 rows) block needs 3 row groups.
        let g = CellGeometry { ou_rows: 4, ..geom() };
        let w = crate::nn::Tensor::from_vec(&[2, 1, 3, 3], vec![1.0; 18]);
        let l = ConvLayer { name: "t".into(), cout: 2, cin: 1, fmap: 4 };
        let ml = PatternMapping.map_layer(0, &l, &w, &g);
        let tasks = enumerate_ous(&ml);
        // 9 rows -> groups of 4,4,1; 8 cells wide -> 1 col group
        assert_eq!(tasks.len(), 3);
        assert_eq!(tasks[0].rows, 4);
        assert_eq!(tasks[2].rows, 1);
        validate_schedule(&ml, &tasks, &g).unwrap();
    }

    #[test]
    fn count_matches_layer_helper() {
        let mut rng = Rng::seed_from(5);
        let w = generate_layer(48, 6, 6, 0.82, 0.35, &mut rng);
        let l = ConvLayer { name: "t".into(), cout: 48, cin: 6, fmap: 8 };
        let ml = PatternMapping.map_layer(0, &l, &w, &geom());
        let tasks = enumerate_ous(&ml);
        assert_eq!(tasks.len(), ml.ou_ops_per_position());
        validate_schedule(&ml, &tasks, &geom()).unwrap();
    }

    /// Property: the schedule always tiles every block exactly, for
    /// arbitrary OU sizes and layers.
    #[test]
    fn prop_schedule_exact_cover() {
        prop::check("ou schedule exact cover", 32, |rng: &mut Rng| {
            let g = CellGeometry {
                ou_rows: rng.range(1, 12),
                ou_cols: rng.range(1, 12),
                ..geom()
            };
            let cout = rng.range(1, 40);
            let cin = rng.range(1, 5);
            let n_pat = rng.range(1, 8).min(cout * cin);
            let w = generate_layer(cout, cin, n_pat, 0.7, 0.2, rng);
            let l = ConvLayer { name: "t".into(), cout, cin, fmap: 4 };
            let ml = PatternMapping.map_layer(0, &l, &w, &g);
            let tasks = enumerate_ous(&ml);
            validate_schedule(&ml, &tasks, &g).unwrap();
        });
    }
}
