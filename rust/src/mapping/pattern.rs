//! The paper's contribution: pattern-pruned, kernel-reordering weight
//! mapping (§III-B, Fig. 4).
//!
//! Per input channel: kernels are grouped by pattern (reordering), the
//! all-zero pattern's kernels are deleted outright, and each group is
//! compressed to a `pattern_size × n_kernels` block by removing zero
//! rows. Blocks are ordered **pattern-major** — "we reorder all the
//! blocks according to the pattern size", and §III-B stores the indexes
//! "pattern by pattern in the same order as mapping" — i.e. every
//! channel's block of the biggest pattern first, then the next pattern,
//! with channels in order inside a pattern ("channel by channel").
//! Same-pattern blocks have near-equal widths, which is what lets the
//! Fig. 5 placement (`placement.rs`) pack them almost losslessly.

use std::collections::BTreeMap;

use super::placement::place_blocks;
use super::{MappedLayer, MappingScheme, PatternBlock};
use crate::nn::{ConvLayer, Tensor};
use crate::pruning::{kernel_slice, Pattern};
use crate::xbar::CellGeometry;

/// Block ordering fed to the Fig. 5 placer.
///
/// The paper's text ("reorder all the blocks according to the pattern
/// size") is ambiguous about tie-breaks; its reported results ("very
/// close to the theoretical best") are only reachable when groups hold
/// near-equal-width blocks, which `WidthThenSize` guarantees — so that
/// is the default. Ablation A4 compares all three orders.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BlockOrder {
    /// Literal-text order: pattern size desc, then pattern, then channel
    /// ("channel by channel" within a pattern).
    SizeThenChannel,
    /// Packing-optimized (ablation A4): pattern size desc, then block
    /// width desc — contiguous near-equal widths minimize the grey
    /// cells. The index buffer encodes `cin` per block, so §IV-C decode
    /// is unaffected.
    SizeThenWidth,
    /// Width-major (default): block width desc, then pattern size
    /// desc. Groups hold near-equal-width blocks, so side waste nearly
    /// vanishes — matching the paper's "very close to the theoretical
    /// best" packing (measured 4.8x/5.2x/3.9x vs the paper's
    /// 4.67x/5.20x/4.16x).
    #[default]
    WidthThenSize,
}

/// The kernel-reordering pattern mapping scheme.
#[derive(Debug, Clone, Default)]
pub struct PatternMapping;

/// Pattern mapping with an explicit block order (ablation variant).
#[derive(Debug, Clone)]
pub struct PatternMappingOrdered(pub BlockOrder);

impl MappingScheme for PatternMappingOrdered {
    fn name(&self) -> &'static str {
        match self.0 {
            BlockOrder::SizeThenChannel => "pattern-sizeorder",
            BlockOrder::SizeThenWidth => "pattern-widthsort",
            BlockOrder::WidthThenSize => "pattern",
        }
    }

    fn map_layer(
        &self,
        layer_idx: usize,
        layer: &ConvLayer,
        weights: &Tensor,
        geom: &CellGeometry,
    ) -> MappedLayer {
        map_layer_ordered(layer_idx, layer, weights, geom, self.0)
    }
}

impl PatternMapping {
    /// Build the (unplaced) pattern blocks of one layer, in placement
    /// order. Exposed for tests and for the index-buffer encoder.
    pub fn build_blocks(
        layer: &ConvLayer,
        w: &Tensor,
        geom: &CellGeometry,
    ) -> (Vec<PatternBlock>, usize) {
        Self::build_blocks_ordered(layer, w, geom, BlockOrder::default())
    }

    /// `build_blocks` with an explicit ordering policy.
    pub fn build_blocks_ordered(
        layer: &ConvLayer,
        w: &Tensor,
        geom: &CellGeometry,
        order: BlockOrder,
    ) -> (Vec<PatternBlock>, usize) {
        let mut zero_kernels = 0usize;
        let max_kernels_per_block = geom.weights_per_row().max(1);

        // Group kernels by (pattern, input channel) — the reordering.
        let mut groups: BTreeMap<(Pattern, usize), Vec<u32>> = BTreeMap::new();
        for cin in 0..layer.cin {
            for cout in 0..layer.cout {
                let p = Pattern::from_kernel(kernel_slice(w, cout, cin));
                if p.is_zero() {
                    zero_kernels += 1; // deleted: never stored or computed
                    continue;
                }
                groups.entry((p, cin)).or_default().push(cout as u32);
            }
        }

        // Pattern-major order: pattern size descending (Fig. 5's "place
        // the pattern block with the biggest pattern size" first), then
        // pattern id for determinism, then channel ("channel by
        // channel" within a pattern) or width (packing ablation).
        let mut ordered: Vec<((Pattern, usize), Vec<u32>)> =
            groups.into_iter().collect();
        match order {
            BlockOrder::SizeThenChannel => ordered.sort_by(|a, b| {
                let (pa, ca) = a.0;
                let (pb, cb) = b.0;
                pb.size()
                    .cmp(&pa.size())
                    .then(pa.0.cmp(&pb.0))
                    .then(ca.cmp(&cb))
            }),
            BlockOrder::SizeThenWidth => ordered.sort_by(|a, b| {
                let (pa, ca) = a.0;
                let (pb, cb) = b.0;
                pb.size()
                    .cmp(&pa.size())
                    .then(b.1.len().cmp(&a.1.len()))
                    .then(pa.0.cmp(&pb.0))
                    .then(ca.cmp(&cb))
            }),
            BlockOrder::WidthThenSize => ordered.sort_by(|a, b| {
                let (pa, ca) = a.0;
                let (pb, cb) = b.0;
                // widths compared post-split, so compare capped kernel
                // counts first, then exact counts
                b.1.len()
                    .cmp(&a.1.len())
                    .then(pb.size().cmp(&pa.size()))
                    .then(pa.0.cmp(&pb.0))
                    .then(ca.cmp(&cb))
            }),
        }

        let mut blocks = Vec::new();
        for ((pat, cin), outs) in ordered {
            // Split blocks wider than one crossbar row.
            for chunk in outs.chunks(max_kernels_per_block) {
                let positions = pat.positions();
                let mut weights =
                    Vec::with_capacity(positions.len() * chunk.len());
                for &pos in &positions {
                    for &oc in chunk {
                        weights.push(kernel_slice(w, oc as usize, cin)[pos]);
                    }
                }
                blocks.push(PatternBlock {
                    cin,
                    pattern: pat,
                    out_channels: chunk.to_vec(),
                    weights,
                });
            }
        }
        (blocks, zero_kernels)
    }
}

fn map_layer_ordered(
    layer_idx: usize,
    layer: &ConvLayer,
    weights: &Tensor,
    geom: &CellGeometry,
    order: BlockOrder,
) -> MappedLayer {
    let (blocks, zero_kernels) =
        PatternMapping::build_blocks_ordered(layer, weights, geom, order);
    let extents: Vec<(usize, usize)> = blocks
        .iter()
        .map(|b| (b.rows(), geom.weight_cols(b.kernels())))
        .collect();
    let placed = place_blocks(&extents, geom);
    let used_cells = extents.iter().map(|(h, w)| h * w).sum();
    MappedLayer {
        layer_idx,
        cout: layer.cout,
        cin: layer.cin,
        geom: *geom,
        blocks,
        placements: placed.placements,
        n_crossbars: placed.n_crossbars,
        used_cells,
        zero_kernels,
    }
}

impl MappingScheme for PatternMapping {
    fn name(&self) -> &'static str {
        "pattern"
    }

    fn map_layer(
        &self,
        layer_idx: usize,
        layer: &ConvLayer,
        weights: &Tensor,
        geom: &CellGeometry,
    ) -> MappedLayer {
        map_layer_ordered(layer_idx, layer, weights, geom, BlockOrder::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::mapping::reconstruct_dense;
    use crate::nn::ConvLayer;
    use crate::pruning::synthetic::generate_layer;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn geom() -> CellGeometry {
        CellGeometry::from_hw(&HardwareConfig::default())
    }

    fn layer(cout: usize, cin: usize) -> ConvLayer {
        ConvLayer { name: "t".into(), cout, cin, fmap: 8 }
    }

    /// The paper's Fig. 4 case study: 1 input channel, 16 kernels, 4
    /// patterns (one all-zero). Naive needs a 9x16-weight region; the
    /// pattern scheme stores everything in 2x9 weights.
    #[test]
    fn paper_fig4_case_study() {
        let g = CellGeometry {
            cells_per_weight: 1,
            ..geom()
        };
        // patterns: A = {0,4} (6 kernels), B = {2,6} (4), C = {4,8} (2),
        // zero (4 kernels) -> sizes all 2.
        let pats = [
            (0b000010001u16, vec![0usize, 3, 5, 8, 11, 14]),
            (0b001000100, vec![1, 6, 9, 12]),
            (0b100010000, vec![2, 7]),
        ];
        let mut w = Tensor::zeros(&[16, 1, 3, 3]);
        for (pid, kernels) in &pats {
            for &k in kernels {
                for pos in Pattern(*pid).positions() {
                    w.set4(k, 0, pos / 3, pos % 3, (k + pos) as f32 + 1.0);
                }
            }
        }
        let ml = PatternMapping.map_layer(0, &layer(16, 1), &w, &g);
        ml.validate().unwrap();
        assert_eq!(ml.zero_kernels, 4);
        assert_eq!(ml.blocks.len(), 3);
        // every block is 2 rows tall; total stored kernels = 12
        assert!(ml.blocks.iter().all(|b| b.rows() == 2));
        let stored: usize = ml.blocks.iter().map(|b| b.kernels()).sum();
        assert_eq!(stored, 12);
        // All fits in one crossbar; used cells = 2*12 = 24 (vs 9*16=144
        // for naive) — the paper's "2x9 crossbar array" compression.
        assert_eq!(ml.n_crossbars, 1);
        assert_eq!(ml.used_cells, 24);
    }

    #[test]
    fn reconstruction_is_lossless() {
        let mut rng = Rng::seed_from(3);
        let w = generate_layer(32, 8, 6, 0.8, 0.3, &mut rng);
        let ml = PatternMapping.map_layer(0, &layer(32, 8), &w, &geom());
        ml.validate().unwrap();
        let back = reconstruct_dense(&ml);
        assert_eq!(back.data, w.data);
    }

    #[test]
    fn all_zero_layer_maps_to_nothing() {
        let w = Tensor::zeros(&[8, 4, 3, 3]);
        let ml = PatternMapping.map_layer(0, &layer(8, 4), &w, &geom());
        assert_eq!(ml.blocks.len(), 0);
        assert_eq!(ml.n_crossbars, 0);
        assert_eq!(ml.zero_kernels, 32);
        assert_eq!(ml.ou_ops_per_position(), 0);
    }

    #[test]
    fn dense_layer_keeps_everything() {
        let w = Tensor::from_vec(&[4, 2, 3, 3], vec![1.0; 72]);
        let ml = PatternMapping.map_layer(0, &layer(4, 2), &w, &geom());
        ml.validate().unwrap();
        assert_eq!(ml.zero_kernels, 0);
        // one FULL pattern block per channel
        assert_eq!(ml.blocks.len(), 2);
        assert!(ml.blocks.iter().all(|b| b.pattern == Pattern::FULL));
        assert_eq!(ml.used_cells, 72 * 4); // cpw = 4
    }

    #[test]
    fn wide_blocks_split_at_crossbar_width() {
        // 512 kernels share one pattern -> 512*4 cells = 4 crossbar rows
        // worth; split into chunks of 128 kernels.
        let mut w = Tensor::zeros(&[512, 1, 3, 3]);
        for k in 0..512 {
            w.set4(k, 0, 0, 0, 1.0);
            w.set4(k, 0, 2, 2, 2.0);
        }
        let ml = PatternMapping.map_layer(0, &layer(512, 1), &w, &geom());
        ml.validate().unwrap();
        assert_eq!(ml.blocks.len(), 4);
        assert!(ml.blocks.iter().all(|b| b.kernels() == 128));
        assert!(ml
            .placements
            .iter()
            .all(|p| p.cols == 512 && p.col == 0));
    }

    #[test]
    fn blocks_ordered_width_major() {
        let mut rng = Rng::seed_from(9);
        let w = generate_layer(64, 4, 8, 0.85, 0.4, &mut rng);
        let g = geom();
        let (blocks, _) = PatternMapping::build_blocks(&layer(64, 4), &w, &g);
        for pair in blocks.windows(2) {
            let (a, b) = (&pair[0], &pair[1]);
            assert!(a.kernels() >= b.kernels(), "width descending");
            if a.kernels() == b.kernels() {
                assert!(a.rows() >= b.rows(), "size desc within equal width");
            }
        }
    }

    #[test]
    fn literal_text_order_still_available() {
        use super::BlockOrder;
        let mut rng = Rng::seed_from(9);
        let w = generate_layer(64, 4, 8, 0.85, 0.4, &mut rng);
        let g = geom();
        let (blocks, _) = PatternMapping::build_blocks_ordered(
            &layer(64, 4), &w, &g, BlockOrder::SizeThenChannel);
        for pair in blocks.windows(2) {
            let (a, b) = (&pair[0], &pair[1]);
            assert!(a.rows() >= b.rows(), "pattern size descending");
            if a.pattern == b.pattern {
                assert!(a.cin <= b.cin, "channel order within a pattern");
            }
        }
    }

    /// Property: mapping is lossless and in-bounds for arbitrary
    /// synthetic pattern-pruned layers.
    #[test]
    fn prop_mapping_lossless() {
        prop::check("pattern mapping lossless", 32, |rng: &mut Rng| {
            let cout = rng.range(1, 48);
            let cin = rng.range(1, 6);
            let n_pat = rng.range(1, 9).min(cout * cin);
            let sparsity = 0.5 + rng.f64() * 0.45;
            let zr = rng.f64() * 0.5;
            let w = generate_layer(cout, cin, n_pat, sparsity, zr, rng);
            let ml = PatternMapping.map_layer(0, &layer(cout, cin), &w, &geom());
            ml.validate().unwrap();
            let back = reconstruct_dense(&ml);
            assert_eq!(back.data, w.data, "reconstruction mismatch");
        });
    }
}
