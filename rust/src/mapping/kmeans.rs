//! Baseline [15] (Lin et al., ASP-DAC'19): k-means column clustering +
//! crossbar-grained pruning.
//!
//! Filter columns of the dense weight matrix are clustered by their
//! zero-structure and reordered so that zero-heavy columns gather;
//! crossbars whose entire region is zero are then pruned. The paper
//! reports this saves only 6–22% of crossbars — the comparison series in
//! Fig. 7's reproduction.

use super::{MappedLayer, MappingScheme, PatternBlock, Placement};
use crate::nn::{ConvLayer, Tensor};
use crate::pruning::{kernel_slice, Pattern};
use crate::util::rng::Rng;
use crate::xbar::CellGeometry;

/// k-means column-clustered crossbar-pruned mapping.
#[derive(Debug, Clone)]
pub struct KmeansMapping {
    pub iterations: usize,
    pub seed: u64,
}

impl Default for KmeansMapping {
    fn default() -> Self {
        KmeansMapping { iterations: 10, seed: 0xC10C }
    }
}

impl KmeansMapping {
    /// Cluster filter columns by zero-mask; returns the column order.
    fn column_order(&self, layer: &ConvLayer, w: &Tensor, k: usize) -> Vec<usize> {
        let cout = layer.cout;
        let dim = layer.cin; // per-channel nonzero count as the feature
        // Feature: for each filter, fraction of nonzeros per input channel
        // (compact stand-in for the full 9*cin zero-mask; preserves the
        // structure k-means needs at VGG scale).
        let feats: Vec<Vec<f32>> = (0..cout)
            .map(|oc| {
                (0..dim)
                    .map(|ic| {
                        let ker = kernel_slice(w, oc, ic);
                        ker.iter().filter(|v| **v != 0.0).count() as f32 / 9.0
                    })
                    .collect()
            })
            .collect();

        let k = k.clamp(1, cout);
        let mut rng = Rng::seed_from(self.seed);
        // init: sample k distinct columns as centroids
        let mut centroids: Vec<Vec<f32>> = rng
            .sample_indices(cout, k)
            .into_iter()
            .map(|i| feats[i].clone())
            .collect();
        let mut assign = vec![0usize; cout];
        for _ in 0..self.iterations {
            // assign
            for (i, f) in feats.iter().enumerate() {
                let mut best = 0;
                let mut best_d = f32::INFINITY;
                for (c, cent) in centroids.iter().enumerate() {
                    let d: f32 = f
                        .iter()
                        .zip(cent.iter())
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    if d < best_d {
                        best_d = d;
                        best = c;
                    }
                }
                assign[i] = best;
            }
            // update
            for (c, cent) in centroids.iter_mut().enumerate() {
                let members: Vec<&Vec<f32>> = feats
                    .iter()
                    .zip(assign.iter())
                    .filter(|(_, a)| **a == c)
                    .map(|(f, _)| f)
                    .collect();
                if members.is_empty() {
                    continue;
                }
                for (d, slot) in cent.iter_mut().enumerate() {
                    *slot = members.iter().map(|m| m[d]).sum::<f32>()
                        / members.len() as f32;
                }
            }
        }
        // order columns cluster by cluster, sparsest cluster first
        let mut cluster_density: Vec<(usize, f32)> = (0..k)
            .map(|c| {
                let members: Vec<usize> = (0..cout).filter(|i| assign[*i] == c).collect();
                let dens = members
                    .iter()
                    .map(|&i| feats[i].iter().sum::<f32>())
                    .sum::<f32>()
                    / members.len().max(1) as f32;
                (c, dens)
            })
            .collect();
        cluster_density.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let mut order = Vec::with_capacity(cout);
        for (c, _) in cluster_density {
            for i in 0..cout {
                if assign[i] == c {
                    order.push(i);
                }
            }
        }
        order
    }
}

impl MappingScheme for KmeansMapping {
    fn name(&self) -> &'static str {
        "kmeans"
    }

    fn map_layer(
        &self,
        layer_idx: usize,
        layer: &ConvLayer,
        weights: &Tensor,
        geom: &CellGeometry,
    ) -> MappedLayer {
        let stripes_per_xbar = (geom.xbar_rows / 9).max(1);
        let kernels_per_tile = geom.weights_per_row().max(1);
        let col_tiles = layer.cout.div_ceil(kernels_per_tile);
        let xbar_rows_needed = layer.cin.div_ceil(stripes_per_xbar);
        let order = self.column_order(layer, weights, col_tiles);

        // Decide which crossbars survive: a crossbar (xr, tile) is
        // pruned iff all its weights are zero.
        let mut live = vec![vec![false; col_tiles]; xbar_rows_needed];
        for xr in 0..xbar_rows_needed {
            let c0 = xr * stripes_per_xbar;
            let c1 = (c0 + stripes_per_xbar).min(layer.cin);
            for tile in 0..col_tiles {
                let k0 = tile * kernels_per_tile;
                let k1 = (k0 + kernels_per_tile).min(layer.cout);
                'scan: for cin in c0..c1 {
                    for &oc in &order[k0..k1] {
                        if kernel_slice(weights, oc, cin)
                            .iter()
                            .any(|v| *v != 0.0)
                        {
                            live[xr][tile] = true;
                            break 'scan;
                        }
                    }
                }
            }
        }
        // Renumber surviving crossbars densely.
        let mut xbar_id = vec![vec![usize::MAX; col_tiles]; xbar_rows_needed];
        let mut n_crossbars = 0;
        for xr in 0..xbar_rows_needed {
            for tile in 0..col_tiles {
                if live[xr][tile] {
                    xbar_id[xr][tile] = n_crossbars;
                    n_crossbars += 1;
                }
            }
        }

        let mut blocks = Vec::new();
        let mut placements = Vec::new();
        let mut used_cells = 0usize;
        for cin in 0..layer.cin {
            let xr = cin / stripes_per_xbar;
            let stripe = cin % stripes_per_xbar;
            for tile in 0..col_tiles {
                if !live[xr][tile] {
                    continue;
                }
                let k0 = tile * kernels_per_tile;
                let k1 = (k0 + kernels_per_tile).min(layer.cout);
                let outs: Vec<u32> = order[k0..k1].iter().map(|&o| o as u32).collect();
                let mut wv = Vec::with_capacity(9 * outs.len());
                for pos in 0..9 {
                    for &oc in &outs {
                        wv.push(kernel_slice(weights, oc as usize, cin)[pos]);
                    }
                }
                let cols = geom.weight_cols(outs.len());
                used_cells += 9 * cols;
                blocks.push(PatternBlock {
                    cin,
                    pattern: Pattern::FULL,
                    out_channels: outs,
                    weights: wv,
                });
                placements.push(Placement {
                    xbar: xbar_id[xr][tile],
                    row: stripe * 9,
                    col: 0,
                    rows: 9,
                    cols,
                });
            }
        }

        MappedLayer {
            layer_idx,
            cout: layer.cout,
            cin: layer.cin,
            geom: *geom,
            blocks,
            placements,
            n_crossbars,
            used_cells,
            zero_kernels: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::mapping::naive::NaiveMapping;
    use crate::mapping::reconstruct_dense;
    use crate::pruning::synthetic::generate_layer;
    use crate::util::rng::Rng;

    fn geom() -> CellGeometry {
        CellGeometry::from_hw(&HardwareConfig::default())
    }

    fn layer(cout: usize, cin: usize) -> ConvLayer {
        ConvLayer { name: "t".into(), cout, cin, fmap: 8 }
    }

    #[test]
    fn column_order_is_permutation() {
        let mut rng = Rng::seed_from(1);
        let w = generate_layer(64, 8, 6, 0.85, 0.4, &mut rng);
        let km = KmeansMapping::default();
        let order = km.column_order(&layer(64, 8), &w, 4);
        let mut s = order.clone();
        s.sort_unstable();
        assert_eq!(s, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn reconstruction_lossless() {
        let mut rng = Rng::seed_from(2);
        let w = generate_layer(48, 6, 6, 0.8, 0.3, &mut rng);
        let ml = KmeansMapping::default().map_layer(0, &layer(48, 6), &w, &geom());
        ml.validate().unwrap();
        assert_eq!(reconstruct_dense(&ml).data, w.data);
    }

    #[test]
    fn never_more_crossbars_than_naive() {
        let mut rng = Rng::seed_from(3);
        let w = generate_layer(256, 128, 8, 0.86, 0.41, &mut rng);
        let g = geom();
        let l = layer(256, 128);
        let naive = NaiveMapping.map_layer(0, &l, &w, &g);
        let km = KmeansMapping::default().map_layer(0, &l, &w, &g);
        km.validate().unwrap();
        assert!(km.n_crossbars <= naive.n_crossbars);
    }

    #[test]
    fn dense_weights_prune_nothing() {
        let w = Tensor::from_vec(&[16, 8, 3, 3], vec![1.0; 16 * 8 * 9]);
        let g = geom();
        let l = layer(16, 8);
        let naive = NaiveMapping.map_layer(0, &l, &w, &g);
        let km = KmeansMapping::default().map_layer(0, &l, &w, &g);
        assert_eq!(km.n_crossbars, naive.n_crossbars);
    }
}
