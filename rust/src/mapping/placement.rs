//! The paper's Fig. 5 block placement strategy.
//!
//! Blocks arrive ordered (channel-major, pattern size descending within
//! a channel — see `pattern.rs`). The placer maintains a current
//! *column group*: blocks stack downward, left-aligned to the group's
//! left edge ("place it there and align it left"), as long as the rows
//! remaining below the current block fit the next block and the block
//! fits the crossbar's columns; the group's width is the maximum block
//! width seen. When the rows run out (Fig. 5b) the group is closed —
//! cells right of narrower blocks and rows left below are wasted, the
//! grey cells — and a new group opens to the right of the old one's
//! full width, or on a fresh crossbar when the columns run out.

use super::Placement;
use crate::xbar::CellGeometry;

/// Outcome of placing a sequence of `(rows, cols_cells)` block extents.
#[derive(Debug, Clone, PartialEq)]
pub struct PlacementResult {
    pub placements: Vec<Placement>,
    pub n_crossbars: usize,
    /// Cells wasted inside closed column groups (Fig. 5's grey cells):
    /// side waste from narrower stacked blocks + bottom waste below the
    /// last block of each group.
    pub internal_waste_cells: usize,
}

/// Place blocks with the Fig. 5 strategy. `extents` are `(rows, cols)`
/// in cells; every extent must fit a single crossbar.
pub fn place_blocks(extents: &[(usize, usize)], geom: &CellGeometry) -> PlacementResult {
    let (xr, xc) = (geom.xbar_rows, geom.xbar_cols);
    let mut placements = Vec::with_capacity(extents.len());
    let mut waste = 0usize;

    // Current column group state.
    let mut xbar = 0usize;
    let mut col = 0usize; // left edge of current group
    let mut width = 0usize; // max block width in the group (0 = closed)
    let mut row = 0usize; // next free row within the group
    let mut group_used = 0usize; // cells used by the group's blocks
    let mut any = false;

    for &(h, w) in extents {
        assert!(h <= xr && w <= xc, "block {h}x{w} exceeds crossbar {xr}x{xc}");
        assert!(h > 0 && w > 0, "degenerate block {h}x{w}");
        any = true;
        if width > 0 && row + h <= xr && col + w <= xc {
            // Stack below the previous block, left-aligned (Fig. 5a).
            placements.push(Placement { xbar, row, col, rows: h, cols: w });
            row += h;
            width = width.max(w);
            group_used += h * w;
        } else {
            // Close the current group (Fig. 5b grey cells), open a new
            // one to the right — or on a fresh crossbar.
            waste += (width * xr).saturating_sub(group_used);
            let mut new_col = col + width;
            if new_col + w > xc {
                xbar += 1;
                new_col = 0;
            }
            col = new_col;
            width = w;
            row = h;
            group_used = h * w;
            placements.push(Placement { xbar, row: 0, col, rows: h, cols: w });
        }
    }
    if any {
        waste += (width * xr).saturating_sub(group_used); // final group
    }

    PlacementResult {
        placements,
        n_crossbars: if any { xbar + 1 } else { 0 },
        internal_waste_cells: waste,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn geom(rows: usize, cols: usize) -> CellGeometry {
        CellGeometry {
            xbar_rows: rows,
            xbar_cols: cols,
            cells_per_weight: 1,
            ou_rows: 9,
            ou_cols: 8,
        }
    }

    #[test]
    fn paper_fig5_sequence() {
        // Fig. 5: blocks sorted by size desc on a small crossbar.
        // Crossbar 8 rows: blocks (5,4), (3,4), (2,3), (1,2), (1,2).
        let g = geom(8, 16);
        let r = place_blocks(&[(5, 4), (3, 4), (2, 3), (1, 2), (1, 2)], &g);
        // (5,4) opens group at col 0; (3,4) stacks below (rows 5..8 full);
        // (2,3) doesn't fit (0 rows left) -> new group at col 4;
        // (1,2) stacks below it; (1,2) again below.
        assert_eq!(
            r.placements,
            vec![
                Placement { xbar: 0, row: 0, col: 0, rows: 5, cols: 4 },
                Placement { xbar: 0, row: 5, col: 0, rows: 3, cols: 4 },
                Placement { xbar: 0, row: 0, col: 4, rows: 2, cols: 3 },
                Placement { xbar: 0, row: 2, col: 4, rows: 1, cols: 2 },
                Placement { xbar: 0, row: 3, col: 4, rows: 1, cols: 2 },
            ]
        );
        assert_eq!(r.n_crossbars, 1);
        // waste: group 2 side cells: (3-2)*1 * 2 blocks = 2; bottom:
        // (8-4)*3 = 12 -> 14
        assert_eq!(r.internal_waste_cells, 14);
    }

    #[test]
    fn fig5b_insufficient_rows_opens_new_columns() {
        // One row left behind the current block; next block needs 2 ->
        // new columns, the leftover row is wasted (paper Fig. 5b).
        let g = geom(4, 16);
        let r = place_blocks(&[(3, 4), (2, 4)], &g);
        assert_eq!(r.placements[1], Placement { xbar: 0, row: 0, col: 4, rows: 2, cols: 4 });
        // waste = 1 row * 4 cols (first group) + 2 rows * 4 (second)
        assert_eq!(r.internal_waste_cells, 4 + 8);
    }

    #[test]
    fn wider_block_stacks_and_expands_group() {
        let g = geom(16, 16);
        let r = place_blocks(&[(4, 2), (4, 3)], &g);
        // "align it left": a wider block stacks below while the crossbar
        // has the columns; the group width grows to 3.
        assert_eq!(r.placements[1].col, 0);
        assert_eq!(r.placements[1].row, 4);
        // waste = group width 3 * 16 rows - (8 + 12) used
        assert_eq!(r.internal_waste_cells, 48 - 20);
    }

    #[test]
    fn wider_block_opens_group_when_columns_exhausted() {
        let g = geom(16, 8);
        // first group at col 0 width 6; block (4,6) stacks; next (4,3)
        // still stacks (col 0 + 3 <= 8); then fill rows so a (10, 6)
        // cannot stack -> new group would be at col 6, 6+6 > 8 -> xbar 1
        let r = place_blocks(&[(8, 6), (4, 6), (4, 3), (10, 6)], &g);
        assert_eq!(r.placements[3].xbar, 1);
        assert_eq!(r.placements[3].col, 0);
    }

    #[test]
    fn spills_to_next_crossbar() {
        let g = geom(8, 8);
        let r = place_blocks(&[(8, 6), (8, 6)], &g);
        assert_eq!(r.placements[0].xbar, 0);
        assert_eq!(r.placements[1].xbar, 1);
        assert_eq!(r.n_crossbars, 2);
    }

    #[test]
    fn empty_input() {
        let g = geom(8, 8);
        let r = place_blocks(&[], &g);
        assert_eq!(r.n_crossbars, 0);
        assert!(r.placements.is_empty());
        assert_eq!(r.internal_waste_cells, 0);
    }

    #[test]
    fn exact_fill_no_waste() {
        let g = geom(8, 8);
        let r = place_blocks(&[(4, 8), (4, 8)], &g);
        assert_eq!(r.n_crossbars, 1);
        assert_eq!(r.internal_waste_cells, 0);
    }

    /// Property: placements never overlap, never leave the crossbar, and
    /// used + internal waste <= total area of open groups.
    #[test]
    fn prop_no_overlap_in_bounds() {
        prop::check("placement no overlap", 64, |rng: &mut Rng| {
            let g = geom(32, 32);
            let n = rng.range(1, 40);
            let extents: Vec<(usize, usize)> = (0..n)
                .map(|_| (rng.range(1, 10), rng.range(1, 12)))
                .collect();
            let r = place_blocks(&extents, &g);
            // occupancy check
            let mut grids =
                vec![vec![false; g.xbar_rows * g.xbar_cols]; r.n_crossbars];
            for p in &r.placements {
                assert!(p.row + p.rows <= g.xbar_rows);
                assert!(p.col + p.cols <= g.xbar_cols);
                for rr in p.row..p.row + p.rows {
                    for cc in p.col..p.col + p.cols {
                        let i = rr * g.xbar_cols + cc;
                        assert!(!grids[p.xbar][i], "overlap");
                        grids[p.xbar][i] = true;
                    }
                }
            }
            // conservation: used + waste never exceeds allocated area
            let used: usize = extents.iter().map(|(h, w)| h * w).sum();
            let total = r.n_crossbars * g.xbar_rows * g.xbar_cols;
            assert!(used + r.internal_waste_cells <= total);
        });
    }

    /// Property: identical extents => deterministic placements.
    #[test]
    fn prop_deterministic() {
        prop::check("placement deterministic", 16, |rng: &mut Rng| {
            let g = geom(64, 64);
            let n = rng.range(1, 30);
            let extents: Vec<(usize, usize)> = (0..n)
                .map(|_| (rng.range(1, 10), rng.range(1, 20)))
                .collect();
            assert_eq!(place_blocks(&extents, &g), place_blocks(&extents, &g));
        });
    }
}
