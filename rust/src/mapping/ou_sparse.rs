//! Baseline [12] (Yang et al., ISCA'19 "Sparse ReRAM Engine")-style
//! OU-granular row compression, without pattern regularity.
//!
//! For each input-channel stripe and each OU-wide group of filters, only
//! the kernel-position rows that carry at least one nonzero weight
//! across the group are stored. No kernel reordering happens, so blocks
//! inherit the *union* pattern of their filter group — which is why the
//! paper's pattern-regular reordering packs tighter (ablation A3/A4).

use std::collections::BTreeMap;

use super::placement::place_blocks;
use super::{MappedLayer, MappingScheme, PatternBlock};
use crate::nn::{ConvLayer, Tensor};
use crate::pruning::{kernel_slice, Pattern};
use crate::xbar::CellGeometry;

/// SRE-style OU row compression.
#[derive(Debug, Clone, Default)]
pub struct OuSparseMapping;

impl MappingScheme for OuSparseMapping {
    fn name(&self) -> &'static str {
        "ou_sparse"
    }

    fn map_layer(
        &self,
        layer_idx: usize,
        layer: &ConvLayer,
        weights: &Tensor,
        geom: &CellGeometry,
    ) -> MappedLayer {
        // Filters per OU column group.
        let group_w = (geom.ou_cols / geom.cells_per_weight).max(1);
        let mut blocks = Vec::new();
        let mut zero_kernels = 0usize;

        for cin in 0..layer.cin {
            for k0 in (0..layer.cout).step_by(group_w) {
                let k1 = (k0 + group_w).min(layer.cout);
                // Union pattern over the group for this channel.
                let mut union = 0u16;
                for oc in k0..k1 {
                    union |= Pattern::from_kernel(kernel_slice(weights, oc, cin)).0;
                }
                let pat = Pattern(union);
                if pat.is_zero() {
                    zero_kernels += k1 - k0;
                    continue;
                }
                // Count kernels that are individually all-zero (they still
                // occupy columns here — SRE compresses rows, not columns).
                let positions = pat.positions();
                let outs: Vec<u32> = (k0 as u32..k1 as u32).collect();
                let mut wv = Vec::with_capacity(positions.len() * outs.len());
                for &pos in &positions {
                    for &oc in &outs {
                        wv.push(kernel_slice(weights, oc as usize, cin)[pos]);
                    }
                }
                blocks.push(PatternBlock {
                    cin,
                    pattern: pat,
                    out_channels: outs,
                    weights: wv,
                });
            }
        }

        // Pack blocks with the same Fig. 5 placer (row-major order; SRE
        // packs groups contiguously).
        let extents: Vec<(usize, usize)> = blocks
            .iter()
            .map(|b| (b.rows(), geom.weight_cols(b.kernels())))
            .collect();
        let placed = place_blocks(&extents, geom);
        let used_cells = extents.iter().map(|(h, w)| h * w).sum();

        MappedLayer {
            layer_idx,
            cout: layer.cout,
            cin: layer.cin,
            geom: *geom,
            blocks,
            placements: placed.placements,
            n_crossbars: placed.n_crossbars,
            used_cells,
            zero_kernels,
        }
    }
}

/// Group-size statistics used by the ablation report.
pub fn union_row_stats(layer: &MappedLayer) -> BTreeMap<usize, usize> {
    let mut hist = BTreeMap::new();
    for b in &layer.blocks {
        *hist.entry(b.rows()).or_insert(0) += 1;
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::mapping::pattern::PatternMapping;
    use crate::mapping::{naive::NaiveMapping, reconstruct_dense};
    use crate::pruning::synthetic::generate_layer;
    use crate::util::rng::Rng;

    fn geom() -> CellGeometry {
        CellGeometry::from_hw(&HardwareConfig::default())
    }

    fn layer(cout: usize, cin: usize) -> ConvLayer {
        ConvLayer { name: "t".into(), cout, cin, fmap: 8 }
    }

    #[test]
    fn reconstruction_lossless() {
        let mut rng = Rng::seed_from(1);
        let w = generate_layer(32, 4, 6, 0.8, 0.3, &mut rng);
        let ml = OuSparseMapping.map_layer(0, &layer(32, 4), &w, &geom());
        ml.validate().unwrap();
        assert_eq!(reconstruct_dense(&ml).data, w.data);
    }

    #[test]
    fn between_naive_and_pattern() {
        // union-pattern compression sits between naive (no compression)
        // and the paper's pattern reordering on pattern-pruned weights
        let mut rng = Rng::seed_from(2);
        let w = generate_layer(128, 64, 8, 0.86, 0.4, &mut rng);
        let g = geom();
        let l = layer(128, 64);
        let naive = NaiveMapping.map_layer(0, &l, &w, &g).used_cells;
        let sre = OuSparseMapping.map_layer(0, &l, &w, &g).used_cells;
        let pat = PatternMapping.map_layer(0, &l, &w, &g).used_cells;
        assert!(sre < naive, "sre {sre} vs naive {naive}");
        assert!(pat < sre, "pattern {pat} vs sre {sre}");
    }

    #[test]
    fn whole_zero_groups_deleted() {
        let w = Tensor::zeros(&[8, 2, 3, 3]);
        let ml = OuSparseMapping.map_layer(0, &layer(8, 2), &w, &geom());
        assert!(ml.blocks.is_empty());
        assert_eq!(ml.zero_kernels, 16);
    }

    #[test]
    fn union_stats_histogram() {
        let mut rng = Rng::seed_from(3);
        let w = generate_layer(64, 8, 6, 0.85, 0.35, &mut rng);
        let ml = OuSparseMapping.map_layer(0, &layer(64, 8), &w, &geom());
        let hist = union_row_stats(&ml);
        let total: usize = hist.values().sum();
        assert_eq!(total, ml.blocks.len());
        assert!(hist.keys().all(|k| (1..=9).contains(k)));
    }
}
