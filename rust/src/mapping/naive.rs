//! Baseline: the naive weight mapping of Fig. 1.
//!
//! The layer's dense weight matrix (rows = `cin * 9` unrolled kernel
//! inputs, columns = `cout` filters) is tiled directly onto crossbars.
//! Zero weights still occupy cells ("If a weight is zero, it still needs
//! to occupy an RRAM cell"). Channel stripes (9 rows) are kept whole
//! within a crossbar so OUs stay aligned with kernel patches — the same
//! alignment [13]'s 9-wordline OU implies.
//!
//! Represented with the shared [`PatternBlock`] model: one FULL-pattern
//! block per (input channel, column tile), placed on a regular grid.

use super::{MappedLayer, MappingScheme, PatternBlock, Placement};
use crate::nn::{ConvLayer, Tensor};
use crate::pruning::{kernel_slice, Pattern};
use crate::xbar::CellGeometry;

/// The Fig. 1 naive dense mapping.
#[derive(Debug, Clone, Default)]
pub struct NaiveMapping;

impl MappingScheme for NaiveMapping {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn map_layer(
        &self,
        layer_idx: usize,
        layer: &ConvLayer,
        weights: &Tensor,
        geom: &CellGeometry,
    ) -> MappedLayer {
        let stripes_per_xbar = (geom.xbar_rows / 9).max(1);
        let kernels_per_tile = geom.weights_per_row().max(1);
        let col_tiles = layer.cout.div_ceil(kernels_per_tile);
        // Crossbar grid: rows of crossbars cover input-channel stripes,
        // columns of crossbars cover filter tiles.
        let xbar_rows_needed = layer.cin.div_ceil(stripes_per_xbar);

        let mut blocks = Vec::with_capacity(layer.cin * col_tiles);
        let mut placements = Vec::with_capacity(layer.cin * col_tiles);

        for cin in 0..layer.cin {
            let xbar_r = cin / stripes_per_xbar;
            let stripe = cin % stripes_per_xbar;
            for tile in 0..col_tiles {
                let k0 = tile * kernels_per_tile;
                let k1 = (k0 + kernels_per_tile).min(layer.cout);
                let outs: Vec<u32> = (k0 as u32..k1 as u32).collect();
                // Dense 9 x n_kernels block (zeros stored explicitly).
                let mut w = Vec::with_capacity(9 * outs.len());
                for pos in 0..9 {
                    for &oc in &outs {
                        w.push(kernel_slice(weights, oc as usize, cin)[pos]);
                    }
                }
                let cols = geom.weight_cols(outs.len());
                blocks.push(PatternBlock {
                    cin,
                    pattern: Pattern::FULL,
                    out_channels: outs,
                    weights: w,
                });
                placements.push(Placement {
                    xbar: xbar_r * col_tiles + tile,
                    row: stripe * 9,
                    col: 0,
                    rows: 9,
                    cols,
                });
            }
        }

        MappedLayer {
            layer_idx,
            cout: layer.cout,
            cin: layer.cin,
            geom: *geom,
            blocks,
            placements,
            n_crossbars: xbar_rows_needed * col_tiles,
            used_cells: layer.cin * 9 * geom.weight_cols(layer.cout),
            zero_kernels: 0, // naive never deletes anything
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;
    use crate::mapping::reconstruct_dense;
    use crate::pruning::synthetic::generate_layer;
    use crate::util::rng::Rng;

    fn geom() -> CellGeometry {
        CellGeometry::from_hw(&HardwareConfig::default())
    }

    fn layer(cout: usize, cin: usize) -> ConvLayer {
        ConvLayer { name: "t".into(), cout, cin, fmap: 8 }
    }

    #[test]
    fn small_layer_single_crossbar() {
        let mut rng = Rng::seed_from(1);
        let w = generate_layer(16, 4, 4, 0.7, 0.2, &mut rng);
        let ml = NaiveMapping.map_layer(0, &layer(16, 4), &w, &geom());
        ml.validate().unwrap();
        assert_eq!(ml.n_crossbars, 1);
        assert_eq!(ml.blocks.len(), 4); // one stripe per channel
        // used cells: 4 channels * 9 rows * 16 kernels * 4 cells
        assert_eq!(ml.used_cells, 4 * 9 * 64);
        // reconstruction is exact (zeros included)
        assert_eq!(reconstruct_dense(&ml).data, w.data);
    }

    #[test]
    fn vgg_conv1_crossbar_count() {
        // conv1 of VGG16: 64x64 kernels. rows = 576 -> 2 crossbar rows
        // (56 stripes each); cols = 64*4 = 256 cells -> 1 tile.
        let mut rng = Rng::seed_from(2);
        let w = generate_layer(64, 64, 4, 0.8, 0.3, &mut rng);
        let ml = NaiveMapping.map_layer(0, &layer(64, 64), &w, &geom());
        ml.validate().unwrap();
        assert_eq!(ml.n_crossbars, 2);
    }

    #[test]
    fn big_layer_crossbar_grid() {
        // 512x512: stripes 512/56 = 10 xbar-rows; cols 512*4/512 = 4 tiles
        let w = Tensor::zeros(&[512, 512, 3, 3]);
        let ml = NaiveMapping.map_layer(0, &layer(512, 512), &w, &geom());
        assert_eq!(ml.n_crossbars, 10 * 4);
        // every block is a full 9-row stripe
        assert!(ml.placements.iter().all(|p| p.rows == 9));
        ml.validate().unwrap();
    }

    #[test]
    fn zero_weights_still_occupy_cells() {
        let w = Tensor::zeros(&[8, 2, 3, 3]);
        let ml = NaiveMapping.map_layer(0, &layer(8, 2), &w, &geom());
        assert_eq!(ml.zero_kernels, 0);
        assert_eq!(ml.used_cells, 2 * 9 * 8 * 4);
        assert!(ml.ou_ops_per_position() > 0);
    }

    #[test]
    fn ou_ops_match_dense_formula() {
        let w = Tensor::zeros(&[64, 16, 3, 3]);
        let g = geom();
        let ml = NaiveMapping.map_layer(0, &layer(64, 16), &w, &g);
        // per position: cin stripes (1 row-group each) x ceil(cout*cpw/8)
        let want = 16 * (64 * 4usize).div_ceil(8);
        assert_eq!(ml.ou_ops_per_position(), want);
    }
}
