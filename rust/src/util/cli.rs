//! Tiny CLI argument parser (clap stand-in).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands. Unknown flags are errors; `--help` text is generated
//! from registered options.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    takes_value: bool,
    default: Option<String>,
}

/// Declarative option set + parsed values.
#[derive(Debug, Default)]
pub struct Args {
    specs: Vec<OptSpec>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positional: Vec<String>,
    about: String,
}

impl Args {
    pub fn new(about: &str) -> Args {
        Args { about: about.to_string(), ..Default::default() }
    }

    /// Register `--name <value>` with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Args {
        self.specs.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            takes_value: true,
            default: Some(default.to_string()),
        });
        self
    }

    /// Register a boolean `--name` flag (default false).
    pub fn flag(mut self, name: &str, help: &str) -> Args {
        self.specs.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            takes_value: false,
            default: None,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{}\n\noptions:\n", self.about);
        for spec in &self.specs {
            if spec.takes_value {
                s.push_str(&format!(
                    "  --{} <v>  {} (default: {})\n",
                    spec.name,
                    spec.help,
                    spec.default.as_deref().unwrap_or("")
                ));
            } else {
                s.push_str(&format!("  --{}  {}\n", spec.name, spec.help));
            }
        }
        s
    }

    /// Parse an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(
        mut self,
        argv: I,
    ) -> Result<Args, String> {
        for spec in &self.specs {
            if let Some(d) = &spec.default {
                self.values.insert(spec.name.clone(), d.clone());
            }
            if !spec.takes_value {
                self.flags.insert(spec.name.clone(), false);
            }
        }
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.usage()))?
                    .clone();
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{name} needs a value"))?,
                    };
                    self.values.insert(name, v);
                } else {
                    if inline.is_some() {
                        return Err(format!("--{name} takes no value"));
                    }
                    self.flags.insert(name, true);
                }
            } else {
                self.positional.push(a);
            }
        }
        Ok(self)
    }

    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} not registered"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name} expects an integer"))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name} expects an unsigned integer"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name} expects a number"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        *self
            .flags
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} not registered"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = Args::new("t")
            .opt("n", "10", "count")
            .opt("name", "abc", "label")
            .flag("verbose", "chatty")
            .parse(argv(&["--n", "20", "--verbose"]))
            .unwrap();
        assert_eq!(a.get_usize("n").unwrap(), 20);
        assert_eq!(a.get("name"), "abc");
        assert!(a.get_flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = Args::new("t")
            .opt("x", "1", "")
            .parse(argv(&["--x=5"]))
            .unwrap();
        assert_eq!(a.get_usize("x").unwrap(), 5);
    }

    #[test]
    fn positional_collected() {
        let a = Args::new("t")
            .opt("x", "1", "")
            .parse(argv(&["sub", "--x", "2", "path"]))
            .unwrap();
        assert_eq!(a.positional(), &["sub".to_string(), "path".to_string()]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(Args::new("t").parse(argv(&["--bogus"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(Args::new("t").opt("x", "1", "").parse(argv(&["--x"])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let e = Args::new("about-me")
            .opt("x", "1", "the x")
            .parse(argv(&["--help"]))
            .unwrap_err();
        assert!(e.contains("about-me"));
        assert!(e.contains("--x"));
    }

    #[test]
    fn bad_number_reported() {
        let a = Args::new("t").opt("n", "abc", "").parse(argv(&[])).unwrap();
        assert!(a.get_usize("n").is_err());
    }

    #[test]
    fn u64_parses_large_seeds() {
        let a = Args::new("t")
            .opt("seed", "0", "")
            .parse(argv(&["--seed", "18446744073709551615"]))
            .unwrap();
        assert_eq!(a.get_u64("seed").unwrap(), u64::MAX);
        let b = Args::new("t").opt("seed", "x", "").parse(argv(&[])).unwrap();
        assert!(b.get_u64("seed").is_err());
    }
}
