//! Injectable microsecond clocks for the observability layer.
//!
//! The tracing subsystem ([`crate::obs`]) lives inside the lint's pure
//! scopes (`no-wall-clock-in-pure-paths` covers `src/obs/`), so it
//! never reads wall time itself — every timestamp is a `u64`
//! microsecond count handed in through the [`Clock`] trait. The two
//! implementations live here, in `src/util/`, the one place the
//! serving edge is allowed to touch real time:
//!
//! * [`MonotonicClock`] — microseconds since its own construction
//!   (process-relative, monotonic, never negative). This is what
//!   `serve-http`, the coordinator and the CLI wire in.
//! * [`TestClock`] — a hand-advanced counter, so tests pin exact span
//!   timestamps and byte-stable Chrome trace JSON.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Source of monotonic microsecond timestamps for span recording.
pub trait Clock: Send + Sync {
    /// Microseconds since the clock's epoch (construction time for
    /// [`MonotonicClock`], whatever the test set for [`TestClock`]).
    fn now_us(&self) -> u64;
}

/// Real monotonic time, relative to construction.
#[derive(Debug)]
pub struct MonotonicClock {
    epoch: Instant,
}

impl MonotonicClock {
    pub fn new() -> MonotonicClock {
        MonotonicClock { epoch: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        MonotonicClock::new()
    }
}

impl Clock for MonotonicClock {
    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }
}

/// Convenience: a freshly-epoched real clock, ready to hand to
/// [`crate::obs::Registry::new`].
pub fn monotonic() -> Arc<dyn Clock> {
    Arc::new(MonotonicClock::new())
}

/// Deterministic clock for tests: starts at 0, moves only when told.
#[derive(Debug, Default)]
pub struct TestClock {
    t: AtomicU64,
}

impl TestClock {
    pub fn new() -> TestClock {
        TestClock::default()
    }

    /// Jump to an absolute microsecond value.
    pub fn set(&self, us: u64) {
        self.t.store(us, Ordering::SeqCst);
    }

    /// Advance by `us` microseconds.
    pub fn advance(&self, us: u64) {
        self.t.fetch_add(us, Ordering::SeqCst);
    }
}

impl Clock for TestClock {
    fn now_us(&self) -> u64 {
        self.t.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_clock_never_goes_backwards() {
        let c = MonotonicClock::new();
        let a = c.now_us();
        let b = c.now_us();
        assert!(b >= a);
    }

    #[test]
    fn test_clock_is_hand_driven() {
        let c = TestClock::new();
        assert_eq!(c.now_us(), 0);
        c.advance(10);
        assert_eq!(c.now_us(), 10);
        c.set(1000);
        assert_eq!(c.now_us(), 1000);
        c.advance(5);
        assert_eq!(c.now_us(), 1005);
    }
}
