//! Minimal JSON value model, parser and writer.
//!
//! Replaces serde_json for this crate's needs: config files, artifact
//! metadata produced by `python/compile/aot.py`, and report emission.
//! Supports the full JSON grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null); numbers are kept as `f64`.

use std::collections::BTreeMap;
use std::fmt;

/// Maximum container nesting the parser accepts. Recursive-descent
/// parsing consumes native stack per level, so unbounded depth lets a
/// few KB of `[[[[…` abort the process; 128 is far beyond any document
/// this crate produces or consumes.
pub const MAX_PARSE_DEPTH: usize = 128;

/// Largest magnitude at which every integer is exactly representable
/// as an `f64` (2^53). Integer accessors reject values at or beyond
/// this bound, and the writer only uses integral formatting below it.
pub const MAX_EXACT_INT: f64 = 9_007_199_254_740_992.0;

/// A JSON value. Object keys are ordered (BTreeMap) so output is stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- typed accessors ----

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            // Beyond 2^53 consecutive integers are no longer exactly
            // representable: 9007199254740993 parses to …992 and would
            // pass a bare fract() check while silently being wrong.
            if n >= 0.0 && n.fract() == 0.0 && n < MAX_EXACT_INT {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    /// Integer accessor for values that fit `u64` exactly. Same 2^53
    /// guard as [`Json::as_usize`]: anything at or beyond the f64-exact
    /// range is rejected rather than silently rounded.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n < MAX_EXACT_INT {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    /// Signed integer accessor with the symmetric ±2^53 exactness guard.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| {
            if n.fract() == 0.0 && n.abs() < MAX_EXACT_INT {
                Some(n as i64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; returns Null on any miss.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Compact single-line serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indents.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

/// Convenience constructor for an object from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Convenience constructor for an array of f64s.
pub fn arr_f64(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
}

/// Convenience constructor for an array of usizes.
pub fn arr_usize(v: &[usize]) -> Json {
    Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
}

impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Num(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; emit null like serde_json does.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < MAX_EXACT_INT {
        // Integral formatting only inside the f64-exact range (< 2^53);
        // beyond it the i64 cast would print digits the float no longer
        // actually distinguishes.
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{}", n));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", s)))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: parse low half if present.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                self.i += 5;
                                if self.b[self.i..].starts_with(b"\\u") {
                                    let hex2 = std::str::from_utf8(
                                        &self.b[self.i + 2..self.i + 6],
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(hex2, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 1; // compensate the +5 below
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                        .ok_or_else(|| self.err("bad surrogate"))?
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                self.i -= 0;
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?
                            };
                            s.push(c);
                            self.i += 4; // 'u' + 4 hex handled below (+1)
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, JsonError> {
        if depth >= MAX_PARSE_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, JsonError> {
        if depth >= MAX_PARSE_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value(depth + 1)?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("a").idx(0).as_f64(), Some(1.0));
        assert_eq!(j.get("a").idx(2).get("b"), &Json::Null);
        assert_eq!(j.get("c").as_str(), Some("x"));
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"c\\ A""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\t\"c\\ A"));
    }

    #[test]
    fn parse_unicode_literal() {
        let j = Json::parse("\"héllo→\"").unwrap();
        assert_eq!(j.as_str(), Some("héllo→"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"obj":{"k":-3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::Arr(vec![]).to_string_compact(), "[]");
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::Num(5.0).as_usize(), Some(5));
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
    }

    #[test]
    fn depth_bomb_rejected_without_stack_overflow() {
        // Regression: the recursive-descent parser used to recurse once
        // per nesting level with no cap, so this 100k-deep bomb aborted
        // the process with a stack overflow instead of returning an Err.
        let bomb = "[".repeat(100_000);
        let err = Json::parse(&bomb).unwrap_err();
        assert!(err.msg.contains("nesting too deep"), "{}", err);
        let obomb = "{\"k\":".repeat(100_000);
        assert!(Json::parse(&obomb).is_err());
    }

    #[test]
    fn depth_under_cap_still_parses() {
        let depth = MAX_PARSE_DEPTH - 1;
        let doc = format!("{}1{}", "[".repeat(depth), "]".repeat(depth));
        let j = Json::parse(&doc).unwrap();
        let mut v = &j;
        for _ in 0..depth {
            v = v.idx(0);
        }
        assert_eq!(v.as_f64(), Some(1.0));
        // One level deeper trips the cap.
        let doc = format!("{}1{}", "[".repeat(depth + 1), "]".repeat(depth + 1));
        assert!(Json::parse(&doc).is_err());
    }

    #[test]
    fn integer_accessors_reject_f64_imprecise_magnitudes() {
        // Regression: 9007199254740993 (2^53 + 1) parses to the f64
        // 9007199254740992, which passed the old fract()==0.0 guard and
        // came back as the *wrong* integer.
        let j = Json::parse("9007199254740993").unwrap();
        assert_eq!(j.as_usize(), None);
        assert_eq!(j.as_u64(), None);
        assert_eq!(j.as_i64(), None);
        // 2^53 itself is exact but indistinguishable from 2^53 + 1.
        assert_eq!(Json::parse("9007199254740992").unwrap().as_usize(), None);
        // 2^53 - 1 is the largest exactly-trustworthy integer.
        let j = Json::parse("9007199254740991").unwrap();
        assert_eq!(j.as_usize(), Some(9007199254740991));
        assert_eq!(j.as_u64(), Some(9007199254740991));
        assert_eq!(j.as_i64(), Some(9007199254740991));
        assert_eq!(Json::parse("-9007199254740991").unwrap().as_i64(), Some(-9007199254740991));
        assert_eq!(Json::parse("-9007199254740992").unwrap().as_i64(), None);
    }

    #[test]
    fn writer_integral_threshold_matches_exact_range() {
        // Below 2^53: integral formatting, round-trips exactly.
        let j = Json::Num(9007199254740991.0);
        assert_eq!(j.to_string_compact(), "9007199254740991");
        assert_eq!(Json::parse(&j.to_string_compact()).unwrap(), j);
        // At/above 2^53: f64 Display (shortest round-tripping digits);
        // the old `< 1e15` threshold was past the exact range.
        let j = Json::Num(9007199254740992.0);
        assert_eq!(Json::parse(&j.to_string_compact()).unwrap(), j);
        let j = Json::Num(9.00719925474099e15);
        assert_eq!(Json::parse(&j.to_string_compact()).unwrap(), j);
    }
}
