//! Minimal scoped thread pool (rayon stand-in).
//!
//! `parallel_map` fans a slice of independent jobs over N OS threads via
//! `std::thread::scope` and an atomic work index — plenty for this
//! crate's per-layer mapping and simulation parallelism.

use crate::util::lockcheck::Mutex;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// Number of worker threads to use by default.
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(16)
}

/// Spawn a named OS thread (names surface in panics and debuggers —
/// the serving coordinator labels its dispatcher and pool workers).
pub fn spawn_named<F, T>(name: &str, f: F) -> std::thread::JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    std::thread::Builder::new()
        .name(name.to_string())
        .spawn(f)
        .expect("spawn named thread")
}

/// Map `f` over `items` in parallel, preserving order of results.
///
/// `f` must be `Sync` (it is shared by reference across workers).
/// Panics in workers propagate after the scope joins.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    parallel_map_indexed(items, threads, |_, t| f(t))
}

/// As [`parallel_map`], but `f` also receives each item's index — the
/// DSE sweep runner uses it to tag results with their grid position so
/// downstream artifacts are independent of scheduling order.
///
/// A panicking closure no longer poisons its result slot and surfaces
/// as an opaque unwrap at collection time: each item runs under
/// `catch_unwind`, remaining items are cancelled, and the first panic
/// is re-raised after the scope joins with the item index and the
/// original payload text. (On the `threads == 1` fast path the panic
/// propagates directly — there is no join to defer it past.)
pub fn parallel_map_indexed<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let next = AtomicUsize::new(0);
    let cancelled = AtomicBool::new(false);
    let results: Vec<Mutex<Option<R>>> =
        (0..n).map(|_| Mutex::named("threadpool.slot", None)).collect();
    let first_panic: Mutex<Option<(usize, String)>> =
        Mutex::named("threadpool.first_panic", None);

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                if cancelled.load(Ordering::Relaxed) {
                    break;
                }
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                match catch_unwind(AssertUnwindSafe(|| f(i, &items[i]))) {
                    Ok(r) => *results[i].lock() = Some(r),
                    Err(payload) => {
                        cancelled.store(true, Ordering::Relaxed);
                        let mut fp = first_panic.lock();
                        if fp.is_none() {
                            *fp = Some((i, panic_text(payload.as_ref())));
                        }
                    }
                }
            });
        }
    });

    if let Some((i, msg)) = first_panic.into_inner() {
        panic!("parallel_map_indexed: worker closure panicked on item {i}: {msg}");
    }
    results
        .into_iter()
        .map(|m| m.into_inner().expect("worker left a hole"))
        .collect()
}

/// Human-readable text of a caught panic payload (`panic!` with a
/// string literal or a formatted message covers everything this crate
/// throws).
fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// Parallel for-each over an index range (no results collected).
pub fn parallel_for<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                f(i);
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn map_preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(&items, 8, |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_single_thread_path() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(&items, 1, |x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn map_empty() {
        let items: Vec<u32> = vec![];
        assert!(parallel_map(&items, 4, |x| *x).is_empty());
    }

    #[test]
    fn for_visits_all_once() {
        let counters: Vec<AtomicU64> = (0..500).map(|_| AtomicU64::new(0)).collect();
        parallel_for(500, 8, |i| {
            counters[i].fetch_add(1, Ordering::Relaxed);
        });
        for c in &counters {
            assert_eq!(c.load(Ordering::Relaxed), 1);
        }
    }

    #[test]
    fn spawn_named_sets_thread_name() {
        let h = spawn_named("tp-test-thread", || {
            std::thread::current().name().map(|s| s.to_string())
        });
        let name = h.join().unwrap();
        assert_eq!(name.as_deref(), Some("tp-test-thread"));
    }

    #[test]
    fn indexed_map_passes_grid_positions() {
        let items = vec![10usize, 20, 30];
        let out = parallel_map_indexed(&items, 2, |i, x| (i, *x));
        assert_eq!(out, vec![(0, 10), (1, 20), (2, 30)]);
        // single-thread path agrees
        let out1 = parallel_map_indexed(&items, 1, |i, x| (i, *x));
        assert_eq!(out, out1);
    }

    #[test]
    fn panicking_item_reports_index_and_message() {
        let items: Vec<usize> = (0..64).collect();
        let res = catch_unwind(AssertUnwindSafe(|| {
            parallel_map_indexed(&items, 4, |i, x| {
                if i == 3 {
                    panic!("item exploded: {x}");
                }
                x * 2
            })
        }));
        let payload = res.expect_err("worker panic must propagate to the caller");
        let msg = panic_text(payload.as_ref());
        assert!(msg.contains("item 3"), "index missing: {msg}");
        assert!(msg.contains("item exploded: 3"), "original payload missing: {msg}");
        assert!(msg.contains("parallel_map_indexed"), "context missing: {msg}");
    }

    #[test]
    fn panic_cancels_remaining_items() {
        // items after the failing one are slow; without cancellation the
        // scope join would have to wait for every one of them
        let items: Vec<usize> = (0..256).collect();
        let ran = AtomicUsize::new(0);
        let res = catch_unwind(AssertUnwindSafe(|| {
            parallel_map_indexed(&items, 2, |i, _x| {
                if i == 0 {
                    panic!("first item fails");
                }
                std::thread::sleep(std::time::Duration::from_millis(1));
                ran.fetch_add(1, Ordering::Relaxed);
            })
        }));
        assert!(res.is_err());
        let ran = ran.load(Ordering::Relaxed);
        assert!(ran < items.len() - 1, "cancellation never took effect ({ran} items ran)");
    }

    #[test]
    fn panic_text_handles_payload_kinds() {
        assert_eq!(panic_text(&"literal"), "literal");
        assert_eq!(panic_text(&String::from("formatted")), "formatted");
        assert_eq!(panic_text(&42u32), "<non-string panic payload>");
    }

    #[test]
    fn threads_capped_by_items() {
        // just exercises the path where threads > n
        let items = vec![10, 20];
        assert_eq!(parallel_map(&items, 64, |x| x / 10), vec![1, 2]);
    }
}
