//! Summary statistics and histograms for benches and reports.

/// Running summary of a sample set.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    values: Vec<f64>,
}

impl Summary {
    pub fn new() -> Summary {
        Summary { values: Vec::new() }
    }

    pub fn from_values(values: Vec<f64>) -> Summary {
        Summary { values }
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    /// Fold another summary's samples into this one (multiset union):
    /// merging per-shard summaries is equivalent to having pushed every
    /// sample into a single summary.
    pub fn merge(&mut self, other: &Summary) {
        self.values.extend_from_slice(&other.values);
    }

    /// The raw samples, in push order.
    pub fn values(&self) -> &[f64] {
        &self.values
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self
            .values
            .iter()
            .map(|v| (v - m) * (v - m))
            .sum::<f64>()
            / (n - 1) as f64;
        var.sqrt()
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile via linear interpolation on the sorted sample, q in [0,100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (q / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Ordinary least-squares fit `y ≈ intercept + slope · x`, returned as
/// `(intercept, slope)`. Degenerate inputs — fewer than two points, or
/// `x` with (near-)zero variance — fall back to `(mean(y), 0.0)` so
/// callers get a constant predictor instead of a NaN line.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len(), "linear_fit needs paired samples");
    let n = xs.len();
    if n == 0 {
        return (0.0, 0.0);
    }
    let mx = xs.iter().sum::<f64>() / n as f64;
    let my = ys.iter().sum::<f64>() / n as f64;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    if n < 2 || sxx < 1e-18 {
        return (my, 0.0);
    }
    let sxy: f64 = xs
        .iter()
        .zip(ys.iter())
        .map(|(x, y)| (x - mx) * (y - my))
        .sum();
    let slope = sxy / sxx;
    (my - slope * mx, slope)
}

/// Fixed-bin histogram over `[lo, hi)`.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n_bins: usize) -> Histogram {
        assert!(hi > lo && n_bins > 0);
        Histogram { lo, hi, bins: vec![0; n_bins], underflow: 0, overflow: 0 }
    }

    pub fn add(&mut self, v: f64) {
        if v < self.lo {
            self.underflow += 1;
        } else if v >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let idx = ((v - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[idx.min(n - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

/// Format a nanosecond duration human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{:.1} ns", ns)
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Format a count with thousands separators.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from_values(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.stddev() - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles_interpolate() {
        let s = Summary::from_values(vec![0.0, 10.0]);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(100.0), 10.0);
        assert_eq!(s.percentile(25.0), 2.5);
    }

    #[test]
    fn empty_summary_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn summary_merge_is_multiset_union() {
        let mut a = Summary::from_values(vec![1.0, 2.0]);
        let b = Summary::from_values(vec![3.0, 4.0, 5.0]);
        a.merge(&b);
        assert_eq!(a.len(), 5);
        assert_eq!(a.mean(), 3.0);
        assert_eq!(a.values(), &[1.0, 2.0, 3.0, 4.0, 5.0]);
        // merging an empty summary is a no-op
        a.merge(&Summary::new());
        assert_eq!(a.len(), 5);
    }

    #[test]
    fn linear_fit_recovers_exact_line() {
        let xs = [0.0, 0.25, 0.5, 1.0];
        let ys: Vec<f64> = xs.iter().map(|x| 100.0 - 40.0 * x).collect();
        let (b, m) = linear_fit(&xs, &ys);
        assert!((b - 100.0).abs() < 1e-9, "intercept {b}");
        assert!((m + 40.0).abs() < 1e-9, "slope {m}");
    }

    #[test]
    fn linear_fit_degenerate_inputs() {
        assert_eq!(linear_fit(&[], &[]), (0.0, 0.0));
        let (b, m) = linear_fit(&[2.0], &[7.0]);
        assert_eq!((b, m), (7.0, 0.0));
        // zero x-variance: constant predictor at mean(y)
        let (b, m) = linear_fit(&[3.0, 3.0, 3.0], &[1.0, 2.0, 3.0]);
        assert_eq!(m, 0.0);
        assert!((b - 2.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_bins() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(10.0);
        assert_eq!(h.bins, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500.0 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
        assert_eq!(fmt_count(1234567), "1,234,567");
        assert_eq!(fmt_count(12), "12");
    }
}
