//! Summary statistics and histograms for benches and reports.

/// Running summary of a sample set.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    values: Vec<f64>,
}

impl Summary {
    pub fn new() -> Summary {
        Summary { values: Vec::new() }
    }

    pub fn from_values(values: Vec<f64>) -> Summary {
        Summary { values }
    }

    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn stddev(&self) -> f64 {
        let n = self.values.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self
            .values
            .iter()
            .map(|v| (v - m) * (v - m))
            .sum::<f64>()
            / (n - 1) as f64;
        var.sqrt()
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Percentile via linear interpolation on the sorted sample, q in [0,100].
    pub fn percentile(&self, q: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (q / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Fixed-bin histogram over `[lo, hi)`.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub bins: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, n_bins: usize) -> Histogram {
        assert!(hi > lo && n_bins > 0);
        Histogram { lo, hi, bins: vec![0; n_bins], underflow: 0, overflow: 0 }
    }

    pub fn add(&mut self, v: f64) {
        if v < self.lo {
            self.underflow += 1;
        } else if v >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let idx = ((v - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[idx.min(n - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.underflow + self.overflow
    }
}

/// Format a nanosecond duration human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{:.1} ns", ns)
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Format a count with thousands separators.
pub fn fmt_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from_values(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.stddev() - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles_interpolate() {
        let s = Summary::from_values(vec![0.0, 10.0]);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(100.0), 10.0);
        assert_eq!(s.percentile(25.0), 2.5);
    }

    #[test]
    fn empty_summary_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
    }

    #[test]
    fn histogram_bins() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.add(i as f64 + 0.5);
        }
        h.add(-1.0);
        h.add(10.0);
        assert_eq!(h.bins, vec![1; 10]);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.total(), 12);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500.0 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
        assert_eq!(fmt_count(1234567), "1,234,567");
        assert_eq!(fmt_count(12), "12");
    }
}
