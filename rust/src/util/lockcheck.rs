//! Poison-recovering, optionally order-checked mutex wrapper — the
//! dynamic half of the determinism/concurrency pass (the static half is
//! `rram-accel lint`'s `mutex-discipline` rule, which points here).
//!
//! [`Mutex`] always recovers from poisoning: a worker that panics while
//! holding a guard must not wedge the surviving pool (`merged_metrics`
//! / `worker_stats` keep working), so `lock()` takes the inner value
//! out of a `PoisonError` instead of propagating it. The protected data
//! stays whatever the panicking thread left behind — callers that need
//! transactional updates must not panic mid-update, which the
//! coordinator's single-`push`/single-assignment usage satisfies.
//!
//! With `--features lockcheck` every acquisition is instrumented:
//!
//! * a per-thread acquisition stack records which named locks the
//!   thread currently holds;
//! * a global, deterministic (BTreeMap) edge graph records every
//!   observed `held → acquired` ordering, with the acquisition chain
//!   that first established it;
//! * acquiring `B` while holding `A` when `B → … → A` is already on
//!   record **panics with both conflicting chains** — the current hold
//!   stack and the previously recorded chain — turning a potential
//!   deadlock into a deterministic test failure;
//! * re-acquiring a lock the thread already holds panics (self
//!   deadlock);
//! * acquisitions that had to wait are counted per lock name
//!   ([`contention_report`]).
//!
//! The probe costs a `try_lock` plus map updates per acquisition, so it
//! is compiled out by default; CI runs the full test suite under the
//! feature (`cargo test --features lockcheck`) in the `lockcheck` job.
//! Locks created with [`Mutex::new`] get the anonymous name and are
//! exempt from order tracking (distinct anonymous locks would alias one
//! graph node); anything held together with another lock should use
//! [`Mutex::named`].

use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{Mutex as StdMutex, MutexGuard, PoisonError};
#[cfg(feature = "lockcheck")]
use std::sync::TryLockError;

/// Name given by [`Mutex::new`]; exempt from order tracking.
const ANON: &str = "<anon>";

/// A `std::sync::Mutex` wrapper: poison-recovering `lock()`, and
/// lock-order + contention instrumentation under `--features
/// lockcheck`.
pub struct Mutex<T> {
    name: &'static str,
    inner: StdMutex<T>,
}

impl<T> Mutex<T> {
    /// An anonymous lock (no order tracking — see module docs).
    pub const fn new(value: T) -> Mutex<T> {
        Mutex::named(ANON, value)
    }

    /// A named lock. Names identify nodes in the global order graph, so
    /// use one distinct `&'static str` per lock *role* (all instances
    /// of a role share ordering constraints, which is exactly what the
    /// probe should check).
    pub const fn named(name: &'static str, value: T) -> Mutex<T> {
        Mutex { name, inner: StdMutex::new(value) }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Acquire, recovering the inner value if a previous holder
    /// panicked. Under `lockcheck` this also asserts lock order and
    /// counts contended acquisitions.
    pub fn lock(&self) -> Guard<'_, T> {
        #[cfg(feature = "lockcheck")]
        let inner = {
            probe::on_acquire(self.name);
            match self.inner.try_lock() {
                Ok(g) => g,
                Err(TryLockError::Poisoned(p)) => p.into_inner(),
                Err(TryLockError::WouldBlock) => {
                    probe::on_contended(self.name);
                    self.inner.lock().unwrap_or_else(PoisonError::into_inner)
                }
            }
        };
        #[cfg(not(feature = "lockcheck"))]
        let inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        Guard {
            inner,
            #[cfg(feature = "lockcheck")]
            name: self.name,
        }
    }

    /// Consume the lock, recovering from poison.
    pub fn into_inner(self) -> T {
        self.inner.into_inner().unwrap_or_else(PoisonError::into_inner)
    }
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Mutex<T> {
        Mutex::new(T::default())
    }
}

impl<T: fmt::Debug> fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Mutex")
            .field("name", &self.name)
            .field("inner", &self.inner)
            .finish()
    }
}

/// RAII guard; releases the lock (and pops the probe's per-thread
/// acquisition stack) on drop.
pub struct Guard<'a, T> {
    inner: MutexGuard<'a, T>,
    #[cfg(feature = "lockcheck")]
    name: &'static str,
}

impl<T> Deref for Guard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.inner
    }
}

impl<T> DerefMut for Guard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.inner
    }
}

#[cfg(feature = "lockcheck")]
impl<T> Drop for Guard<'_, T> {
    fn drop(&mut self) {
        probe::on_release(self.name);
    }
}

/// Contended-acquisition counts per lock name, sorted by name
/// (deterministic). Always empty without `--features lockcheck`.
pub fn contention_report() -> Vec<(String, u64)> {
    #[cfg(feature = "lockcheck")]
    {
        probe::contention_report()
    }
    #[cfg(not(feature = "lockcheck"))]
    {
        Vec::new()
    }
}

#[cfg(feature = "lockcheck")]
mod probe {
    use super::ANON;
    use std::cell::RefCell;
    use std::collections::BTreeMap;
    use std::sync::{Mutex as StdMutex, PoisonError};

    /// Observed orderings: `held name → (acquired name → chain that
    /// first established the edge)`. BTreeMap keeps traversal (and thus
    /// violation messages) deterministic.
    static EDGES: StdMutex<BTreeMap<&'static str, BTreeMap<&'static str, Vec<&'static str>>>> =
        StdMutex::new(BTreeMap::new());
    /// Acquisitions that found the lock busy, per name.
    static CONTENDED: StdMutex<BTreeMap<&'static str, u64>> = StdMutex::new(BTreeMap::new());

    thread_local! {
        /// Names of locks this thread currently holds, oldest first.
        static HELD: RefCell<Vec<&'static str>> = const { RefCell::new(Vec::new()) };
    }

    /// Record (and validate) an acquisition attempt. Panics on a
    /// same-thread re-acquisition or on an order inversion; the panic
    /// fires *before* blocking on the lock, so a true deadlock becomes
    /// a deterministic failure instead of a hang.
    pub(super) fn on_acquire(name: &'static str) {
        if name == ANON {
            return;
        }
        let conflict = HELD.with(|h| {
            let held = h.borrow();
            if held.contains(&name) {
                return Some(format!(
                    "self-deadlock: thread re-acquired '{name}' while holding [{}]",
                    held.join(" -> ")
                ));
            }
            if held.is_empty() {
                return None;
            }
            let mut edges = EDGES.lock().unwrap_or_else(PoisonError::into_inner);
            for &old in held.iter() {
                if let Some(chain) = find_path(&edges, name, old) {
                    let established = edges
                        .get(chain[0])
                        .and_then(|m| m.get(chain[1]))
                        .cloned()
                        .unwrap_or_default();
                    return Some(format!(
                        "lock order violation: acquiring '{name}' while holding \
                         [{}], but the reverse order [{}] is already on record \
                         (first established by acquisition chain [{}])",
                        held.join(" -> "),
                        chain.join(" -> "),
                        established.join(" -> "),
                    ));
                }
            }
            for &old in held.iter() {
                edges.entry(old).or_default().entry(name).or_insert_with(|| {
                    let mut chain: Vec<&'static str> = held.clone();
                    chain.push(name);
                    chain
                });
            }
            None
        });
        if let Some(msg) = conflict {
            panic!("[lockcheck] {msg}");
        }
        HELD.with(|h| h.borrow_mut().push(name));
    }

    pub(super) fn on_release(name: &'static str) {
        if name == ANON {
            return;
        }
        HELD.with(|h| {
            let mut held = h.borrow_mut();
            if let Some(i) = held.iter().rposition(|&n| n == name) {
                held.remove(i);
            }
        });
    }

    pub(super) fn on_contended(name: &'static str) {
        *CONTENDED
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .entry(name)
            .or_insert(0) += 1;
    }

    pub(super) fn contention_report() -> Vec<(String, u64)> {
        CONTENDED
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .iter()
            .map(|(&n, &c)| (n.to_string(), c))
            .collect()
    }

    /// DFS path `from → … → to` over recorded edges, if any (BTreeMap
    /// order ⇒ deterministic path choice).
    fn find_path(
        edges: &BTreeMap<&'static str, BTreeMap<&'static str, Vec<&'static str>>>,
        from: &'static str,
        to: &'static str,
    ) -> Option<Vec<&'static str>> {
        let mut stack = vec![vec![from]];
        let mut visited = vec![from];
        while let Some(path) = stack.pop() {
            let last = *path.last().expect("path never empty");
            if last == to {
                return Some(path);
            }
            if let Some(next) = edges.get(last) {
                for &n in next.keys() {
                    if !visited.contains(&n) {
                        visited.push(n);
                        let mut p = path.clone();
                        p.push(n);
                        stack.push(p);
                    }
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recovers_from_poison() {
        let m = Arc::new(Mutex::named("lockcheck-test.poison", vec![1u32]));
        let m2 = Arc::clone(&m);
        let h = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("holder dies with the guard");
        });
        assert!(h.join().is_err());
        // a poisoned std mutex would panic here; ours recovers
        m.lock().push(2);
        assert_eq!(m.lock().len(), 2);
    }

    #[test]
    fn into_inner_recovers_from_poison() {
        let m = Arc::new(Mutex::named("lockcheck-test.into-inner", 7u64));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison it");
        })
        .join();
        let m = Arc::into_inner(m).expect("sole owner");
        assert_eq!(m.into_inner(), 7);
    }

    #[test]
    fn guard_derefs_both_ways() {
        let m = Mutex::new(String::from("a"));
        m.lock().push('b');
        assert_eq!(&*m.lock(), "ab");
        assert_eq!(m.name(), "<anon>");
        assert_eq!(Mutex::<u32>::default().into_inner(), 0);
    }

    #[cfg(feature = "lockcheck")]
    mod probe_behavior {
        use super::*;

        fn panic_message(r: std::thread::Result<()>) -> String {
            match r {
                Ok(()) => panic!("expected a lockcheck panic"),
                Err(e) => e
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_default(),
            }
        }

        #[test]
        fn inverted_order_panics_with_both_chains() {
            static A: Mutex<i32> = Mutex::named("order-test.a", 0);
            static B: Mutex<i32> = Mutex::named("order-test.b", 0);
            {
                let _a = A.lock();
                let _b = B.lock(); // records a → b
            }
            let msg = panic_message(
                std::thread::spawn(|| {
                    let _b = B.lock();
                    let _a = A.lock(); // b → a: inversion
                })
                .join(),
            );
            assert!(msg.contains("lock order violation"), "{msg}");
            assert!(msg.contains("order-test.a") && msg.contains("order-test.b"), "{msg}");
            // both chains are in the message: current hold and the record
            assert!(msg.contains("order-test.b -> order-test.a"), "{msg}");
            assert!(msg.contains("order-test.a -> order-test.b"), "{msg}");
            // the probe state recovers: the same thread can still lock A
            let _a = A.lock();
        }

        #[test]
        fn transitive_inversion_detected() {
            static P: Mutex<i32> = Mutex::named("order-test.p", 0);
            static Q: Mutex<i32> = Mutex::named("order-test.q", 0);
            static R: Mutex<i32> = Mutex::named("order-test.r", 0);
            {
                let _p = P.lock();
                let _q = Q.lock(); // p → q
            }
            {
                let _q = Q.lock();
                let _r = R.lock(); // q → r
            }
            let msg = panic_message(
                std::thread::spawn(|| {
                    let _r = R.lock();
                    let _p = P.lock(); // r → p closes the cycle p→q→r→p
                })
                .join(),
            );
            assert!(msg.contains("lock order violation"), "{msg}");
            assert!(msg.contains("order-test.p -> order-test.q -> order-test.r"), "{msg}");
        }

        #[test]
        fn self_reacquisition_panics() {
            static S: Mutex<i32> = Mutex::named("order-test.self", 0);
            let msg = panic_message(
                std::thread::spawn(|| {
                    let _g1 = S.lock();
                    let _g2 = S.lock();
                })
                .join(),
            );
            assert!(msg.contains("self-deadlock"), "{msg}");
        }

        #[test]
        fn contention_is_counted() {
            static C: Mutex<i32> = Mutex::named("order-test.contended", 0);
            let g = C.lock();
            let waiter = std::thread::spawn(|| {
                *C.lock() += 1; // must wait for the main thread
            });
            std::thread::sleep(std::time::Duration::from_millis(50));
            drop(g);
            waiter.join().unwrap();
            let report = contention_report();
            let hit = report
                .iter()
                .find(|(n, _)| n == "order-test.contended")
                .map(|(_, c)| *c)
                .unwrap_or(0);
            assert!(hit >= 1, "expected a contended acquisition, got {report:?}");
        }

        #[test]
        fn consistent_order_is_quiet() {
            static X: Mutex<i32> = Mutex::named("order-test.x", 0);
            static Y: Mutex<i32> = Mutex::named("order-test.y", 0);
            for _ in 0..100 {
                let _x = X.lock();
                let _y = Y.lock();
            }
        }
    }
}
