//! From-scratch utility substrates.
//!
//! This offline image only ships the `xla` crate's dependency closure,
//! so the usual ecosystem crates (serde/clap/criterion/proptest/rand/
//! rayon) are re-implemented here at the scale this project needs.

pub mod bench;
pub mod cli;
pub mod clock;
pub mod json;
pub mod lockcheck;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;

/// FNV-1a 64-bit hash of a string — the crate's one stable string hash,
/// shared by the property-test seed derivation, the synthetic-weight
/// profile seeding and the DSE result-cache keys.
pub fn fnv1a(s: &str) -> u64 {
    fnv1a_bytes(s.as_bytes())
}

/// FNV-1a 64-bit over raw bytes — the record checksum of the binary
/// artifact store ([`crate::store`]). Identical to [`fnv1a`] on the
/// string's UTF-8 bytes.
pub fn fnv1a_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    #[test]
    fn fnv1a_known_vectors() {
        // standard FNV-1a 64 test vectors
        assert_eq!(super::fnv1a(""), 0xcbf29ce484222325);
        assert_eq!(super::fnv1a("a"), 0xaf63dc4c8601ec8c);
        assert_ne!(super::fnv1a("cifar10"), super::fnv1a("cifar100"));
    }
}
