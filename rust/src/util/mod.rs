//! From-scratch utility substrates.
//!
//! This offline image only ships the `xla` crate's dependency closure,
//! so the usual ecosystem crates (serde/clap/criterion/proptest/rand/
//! rayon) are re-implemented here at the scale this project needs.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;
