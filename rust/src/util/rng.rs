//! Deterministic PRNG (SplitMix64 seeding + Xoshiro256**) and the few
//! distributions this crate needs. Stand-in for the `rand` crate.
//!
//! All simulator and generator code takes an explicit `Rng` so every
//! experiment is reproducible from a seed recorded in the report.

/// Xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn seed_from(seed: u64) -> Rng {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (for per-thread / per-layer use).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seed_from(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)` (unbiased via rejection).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal (Box–Muller; one value per call for simplicity).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > f64::EPSILON {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() needs positive total");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_range_and_roughly_uniform() {
        let mut r = Rng::seed_from(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_unbiased_smoke() {
        let mut r = Rng::seed_from(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "count={c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::seed_from(13);
        for _ in 0..50 {
            let k = r.range(1, 20);
            let s = r.sample_indices(30, k);
            assert_eq!(s.len(), k);
            let mut uniq = s.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), k);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::seed_from(19);
        let mut heavy = 0;
        for _ in 0..10_000 {
            if r.weighted(&[1.0, 9.0]) == 1 {
                heavy += 1;
            }
        }
        assert!((8_500..9_500).contains(&heavy), "heavy={heavy}");
    }
}
