//! Property-testing micro-framework (proptest stand-in).
//!
//! `check(name, cases, |rng| ...)` runs a closure against many seeded
//! RNG streams; failures report the exact case seed so the case can be
//! replayed with `check_seed`. No shrinking — generators here are kept
//! small and structured so raw counterexamples are already readable.

use super::rng::Rng;

pub const DEFAULT_CASES: u32 = 128;

/// Case-count override for CI sweeps: when the `PROP_CASES` env var is
/// set (and parseable), it replaces the caller's default — the nightly
/// cron job reruns the same properties at a much higher count.
pub fn cases(default_cases: u32) -> u32 {
    std::env::var("PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default_cases)
}

/// Run `body` for `cases` deterministic seeds. Panics (with the failing
/// seed) on the first failure.
pub fn check<F: Fn(&mut Rng)>(name: &str, cases: u32, body: F) {
    for case in 0..cases {
        let seed = derive_seed(name, case);
        let mut rng = Rng::seed_from(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            body(&mut rng)
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}): {msg}\n\
                 replay with: check_seed(\"{name}\", {seed:#x}, body)"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn check_seed<F: Fn(&mut Rng)>(_name: &str, seed: u64, body: F) {
    let mut rng = Rng::seed_from(seed);
    body(&mut rng);
}

fn derive_seed(name: &str, case: u32) -> u64 {
    // FNV-1a over the name, mixed with the case index.
    super::fnv1a(name) ^ ((case as u64) << 32 | case as u64)
}

// ---- common generators ----

/// Random f32 in [-scale, scale].
pub fn gen_f32(rng: &mut Rng, scale: f32) -> f32 {
    (rng.f32() * 2.0 - 1.0) * scale
}

/// Random vector of f32.
pub fn gen_vec_f32(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| gen_f32(rng, scale)).collect()
}

/// Random sparse vector: each element zero with probability `p_zero`.
pub fn gen_sparse_f32(rng: &mut Rng, len: usize, p_zero: f64, scale: f32) -> Vec<f32> {
    (0..len)
        .map(|_| if rng.chance(p_zero) { 0.0 } else { gen_f32(rng, scale) })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("addition commutes", 64, |rng| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            check("always fails", 4, |_| panic!("boom"));
        });
        let err = r.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("always fails"));
        assert!(msg.contains("seed"));
        assert!(msg.contains("boom"));
    }

    #[test]
    fn deterministic_across_runs() {
        use std::sync::Mutex;
        let first: Mutex<Vec<u64>> = Mutex::new(Vec::new());
        check("det", 8, |rng| {
            first.lock().unwrap().push(rng.next_u64());
        });
        let second: Mutex<Vec<u64>> = Mutex::new(Vec::new());
        check("det", 8, |rng| {
            second.lock().unwrap().push(rng.next_u64());
        });
        assert_eq!(*first.lock().unwrap(), *second.lock().unwrap());
    }

    #[test]
    fn cases_env_override() {
        // PROP_CASES is unset in normal runs -> default passes through.
        // (Set only by the nightly CI job; avoid mutating process env in
        // a parallel test binary.)
        match std::env::var("PROP_CASES")
            .ok()
            .and_then(|v| v.parse::<u32>().ok())
        {
            Some(want) => assert_eq!(cases(7), want),
            None => assert_eq!(cases(7), 7),
        }
    }

    #[test]
    fn sparse_generator_sparsity() {
        let mut rng = Rng::seed_from(5);
        let v = gen_sparse_f32(&mut rng, 10_000, 0.8, 1.0);
        let zeros = v.iter().filter(|x| **x == 0.0).count();
        assert!((7_500..8_500).contains(&zeros), "zeros={zeros}");
    }
}
