//! Hand-rolled benchmark harness (criterion stand-in).
//!
//! Used by every target in `benches/` (`harness = false`). Provides
//! warmup, timed iterations, and a stable one-line report with
//! mean/median/stddev so paper-figure benches double as perf benches.

use std::hint::black_box;
use std::time::{Duration, Instant};

use super::stats::{fmt_ns, Summary};

pub use std::hint::black_box as bb;

#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u32,
    pub max_iters: u32,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter (median {:>12}, sd {:>10}, n={})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.stddev_ns),
            self.iters
        )
    }
}

/// Time `f`, printing a criterion-style line. Returns stats in ns.
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    // Warmup.
    let start = Instant::now();
    let mut warm_iters = 0u32;
    while start.elapsed() < cfg.warmup || warm_iters < 1 {
        f();
        warm_iters += 1;
        if warm_iters >= cfg.max_iters {
            break;
        }
    }

    // Measure.
    let mut samples = Summary::new();
    let measure_start = Instant::now();
    let mut iters = 0u32;
    while (measure_start.elapsed() < cfg.measure || iters < cfg.min_iters)
        && iters < cfg.max_iters
    {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
        iters += 1;
    }

    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_ns: samples.mean(),
        median_ns: samples.median(),
        stddev_ns: samples.stddev(),
        min_ns: samples.min(),
    };
    println!("{}", result.report_line());
    result
}

/// Time a single execution of `f` (for long-running whole-figure jobs).
pub fn time_once<R, F: FnOnce() -> R>(name: &str, f: F) -> (R, Duration) {
    let t = Instant::now();
    let r = black_box(f());
    let d = t.elapsed();
    println!("{:<44} {:>12} (single run)", name, fmt_ns(d.as_nanos() as f64));
    (r, d)
}

/// Throughput helper: items/second from a bench result.
pub fn throughput(result: &BenchResult, items_per_iter: u64) -> f64 {
    items_per_iter as f64 / (result.mean_ns / 1e9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let cfg = BenchConfig {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            min_iters: 3,
            max_iters: 1000,
        };
        let mut count = 0u64;
        let r = bench("noop", &cfg, || {
            count = bb(count + 1);
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ns >= 0.0);
        assert!(count > 0);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once("compute", || 21 * 2);
        assert_eq!(v, 42);
        assert!(d.as_nanos() > 0);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_ns: 1e9,
            median_ns: 1e9,
            stddev_ns: 0.0,
            min_ns: 1e9,
        };
        assert!((throughput(&r, 500) - 500.0).abs() < 1e-9);
    }
}
