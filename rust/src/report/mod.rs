//! Paper table & figure emitters.
//!
//! Every bench target prints its paper artifact through these helpers so
//! the rows are formatted identically across `cargo bench`, the
//! examples, and the CLI, and every result is also emitted as JSON under
//! `results/` for EXPERIMENTS.md.

pub mod artifacts;

use std::path::Path;

use crate::coordinator::{MetricsSnapshot, WorkerStats};
use crate::pruning::synthetic::DatasetProfile;
use crate::pruning::NetworkStats;
use crate::sim::placement::PlacementPlan;
use crate::sim::{Comparison, ShardPlan};
use crate::util::json::{arr_f64, arr_usize, obj, Json};
use crate::xbar::energy::EnergyLedger;

/// Render Table I (hardware parameters) from the live config.
pub fn table1(hw: &crate::config::HardwareConfig) -> String {
    let mut s = String::new();
    s.push_str("TABLE I — HARDWARE PARAMETERS\n");
    s.push_str(&format!(
        "  ADC   {} bits, {} GSps, {} pJ/op\n",
        hw.adc_bits, hw.adc_gsps, hw.adc_pj_per_op
    ));
    s.push_str(&format!(
        "  DAC   {} bits, {} MSps, {} pJ/op\n",
        hw.dac_bits, hw.dac_msps, hw.dac_pj_per_op
    ));
    s.push_str(&format!(
        "  RRAM  OU {}x{}, {} bits/cell, {}x{} array, {} pJ/OU/op\n",
        hw.ou_rows, hw.ou_cols, hw.cell_bits, hw.xbar_rows, hw.xbar_cols,
        hw.rram_pj_per_ou_op
    ));
    s
}

/// One Table II row: paper-published vs measured statistics.
pub fn table2_row(profile: &DatasetProfile, measured: &NetworkStats) -> String {
    format!(
        "{:<10} sparsity {:.2}% (paper {:.2}%)  patterns {:?} (paper {:?})  \
         total {} (paper {})  zero-kernels {:.1}% (paper {:.1}%)  \
         top1 {} top5 {}",
        profile.name,
        measured.sparsity * 100.0,
        profile.sparsity * 100.0,
        measured.patterns_per_layer,
        profile.patterns_per_layer,
        measured.total_patterns,
        profile.patterns_per_layer.iter().sum::<usize>(),
        measured.all_zero_kernel_ratio * 100.0,
        profile.all_zero_ratio * 100.0,
        profile.top1,
        profile.top5,
    )
}

/// Fig. 7 series entry: crossbar counts + area efficiency.
#[derive(Debug, Clone)]
pub struct Fig7Row {
    pub dataset: String,
    pub naive_crossbars: usize,
    pub pattern_crossbars: usize,
    pub kmeans_crossbars: usize,
    pub ou_sparse_crossbars: usize,
    /// 1 / (1 - sparsity): the paper's "theoretical best".
    pub theoretical_best: f64,
    pub paper_efficiency: f64,
}

impl Fig7Row {
    pub fn efficiency(&self) -> f64 {
        self.naive_crossbars as f64 / self.pattern_crossbars.max(1) as f64
    }

    pub fn saved_fraction(&self) -> f64 {
        1.0 - self.pattern_crossbars as f64 / self.naive_crossbars.max(1) as f64
    }

    pub fn line(&self) -> String {
        format!(
            "{:<10} naive {:>5}  pattern {:>4} ({:.2}x, saved {:.1}%; paper {:.2}x)  \
             kmeans {:>5}  ou-sparse {:>4}  theoretical {:.2}x",
            self.dataset,
            self.naive_crossbars,
            self.pattern_crossbars,
            self.efficiency(),
            self.saved_fraction() * 100.0,
            self.paper_efficiency,
            self.kmeans_crossbars,
            self.ou_sparse_crossbars,
            self.theoretical_best,
        )
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("dataset", self.dataset.as_str().into()),
            ("naive_crossbars", self.naive_crossbars.into()),
            ("pattern_crossbars", self.pattern_crossbars.into()),
            ("kmeans_crossbars", self.kmeans_crossbars.into()),
            ("ou_sparse_crossbars", self.ou_sparse_crossbars.into()),
            ("area_efficiency", self.efficiency().into()),
            ("saved_fraction", self.saved_fraction().into()),
            ("theoretical_best", self.theoretical_best.into()),
            ("paper_efficiency", self.paper_efficiency.into()),
        ])
    }
}

/// Fig. 8 entry: normalized energy breakdown (baseline := 1.0).
#[derive(Debug, Clone)]
pub struct Fig8Row {
    pub dataset: String,
    pub baseline: EnergyLedger,
    pub ours: EnergyLedger,
    pub paper_efficiency: f64,
}

impl Fig8Row {
    pub fn efficiency(&self) -> f64 {
        self.baseline.total_pj() / self.ours.total_pj().max(1e-12)
    }

    fn norm(&self, e: &EnergyLedger) -> (f64, f64, f64, f64) {
        let t = self.baseline.total_pj().max(1e-12);
        (e.adc_pj / t, e.dac_pj / t, e.rram_pj / t, e.total_pj() / t)
    }

    pub fn lines(&self) -> String {
        let (ba, bd, br, bt) = self.norm(&self.baseline);
        let (oa, od, or_, ot) = self.norm(&self.ours);
        format!(
            "{:<10} baseline  ADC {:.3} DAC {:.4} RRAM {:.3} | total {:.3}\n\
             {:<10} pattern   ADC {:.3} DAC {:.4} RRAM {:.3} | total {:.3}  \
             -> {:.2}x energy efficiency (paper {:.2}x)",
            self.dataset, ba, bd, br, bt, "", oa, od, or_, ot,
            self.efficiency(), self.paper_efficiency,
        )
    }

    pub fn to_json(&self) -> Json {
        let (ba, bd, br, _) = self.norm(&self.baseline);
        let (oa, od, or_, ot) = self.norm(&self.ours);
        obj(vec![
            ("dataset", self.dataset.as_str().into()),
            ("baseline_adc", ba.into()),
            ("baseline_dac", bd.into()),
            ("baseline_rram", br.into()),
            ("ours_adc", oa.into()),
            ("ours_dac", od.into()),
            ("ours_rram", or_.into()),
            ("ours_total_norm", ot.into()),
            // raw totals alongside the normalized stack: the
            // sampled-vs-exact delta report compares absolute energies
            ("baseline_total_pj", self.baseline.total_pj().into()),
            ("ours_total_pj", self.ours.total_pj().into()),
            ("energy_efficiency", self.efficiency().into()),
            ("paper_efficiency", self.paper_efficiency.into()),
        ])
    }
}

/// One Table II row plus the §V-C speedup it implies: pruning structure
/// statistics (trace-independent) and the simulated naive/pattern cycle
/// totals (trace-dependent) side by side — the third paper artifact the
/// sampled-vs-exact pipeline regenerates in both modes.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub dataset: String,
    pub sparsity: f64,
    pub paper_sparsity: f64,
    pub patterns_per_layer: Vec<usize>,
    pub paper_patterns_per_layer: Vec<usize>,
    pub total_patterns: usize,
    pub all_zero_ratio: f64,
    pub paper_all_zero_ratio: f64,
    pub top1: String,
    pub top5: String,
    /// Simulated whole-network cycles of the naive Fig. 1 baseline.
    pub naive_cycles: f64,
    /// Simulated whole-network cycles of the pattern scheme.
    pub pattern_cycles: f64,
    pub paper_speedup: f64,
}

impl Table2Row {
    pub fn speedup(&self) -> f64 {
        self.naive_cycles / self.pattern_cycles.max(1.0)
    }

    pub fn line(&self) -> String {
        format!(
            "{:<10} sparsity {:.2}% (paper {:.2}%)  patterns {:?} (paper {:?})  \
             total {} (paper {})  zero-kernels {:.1}% (paper {:.1}%)  \
             top1 {} top5 {}  speedup {:.2}x (paper {:.2}x)",
            self.dataset,
            self.sparsity * 100.0,
            self.paper_sparsity * 100.0,
            self.patterns_per_layer,
            self.paper_patterns_per_layer,
            self.total_patterns,
            self.paper_patterns_per_layer.iter().sum::<usize>(),
            self.all_zero_ratio * 100.0,
            self.paper_all_zero_ratio * 100.0,
            self.top1,
            self.top5,
            self.speedup(),
            self.paper_speedup,
        )
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("dataset", self.dataset.as_str().into()),
            ("sparsity", self.sparsity.into()),
            ("paper_sparsity", self.paper_sparsity.into()),
            ("patterns_per_layer", arr_usize(&self.patterns_per_layer)),
            (
                "paper_patterns_per_layer",
                arr_usize(&self.paper_patterns_per_layer),
            ),
            ("total_patterns", self.total_patterns.into()),
            ("all_zero_ratio", self.all_zero_ratio.into()),
            ("paper_all_zero_ratio", self.paper_all_zero_ratio.into()),
            ("naive_cycles", self.naive_cycles.into()),
            ("pattern_cycles", self.pattern_cycles.into()),
            ("speedup", self.speedup().into()),
            ("paper_speedup", self.paper_speedup.into()),
        ])
    }
}

/// §Perf engine line: per-position reference vs trace-aggregated
/// simulator engine (used by `benches/sim_hotpath.rs`).
pub fn engine_speedup_line(reference_ns: f64, aggregated_ns: f64) -> String {
    let ratio = reference_ns / aggregated_ns.max(1e-9);
    format!(
        "  -> aggregated engine {:.1}x reference throughput (target >= 5x: {})",
        ratio,
        if ratio >= 5.0 { "MET" } else { "MISSED" }
    )
}

/// One-line summary of a batched multi-image simulation (the
/// `batch-sim` subcommand and `benches/sim_hotpath.rs`).
pub fn batch_line(r: &crate::sim::BatchSimResult) -> String {
    format!(
        "{:<10} batch of {:>3}: cycles {:>15.0} total  {:>13.0} mean/img  \
         {:>13.0} max/img  energy {:.3e} pJ",
        r.scheme,
        r.n_images(),
        r.total_cycles(),
        r.mean_cycles_per_image(),
        r.max_image_cycles(),
        r.total_energy().total_pj(),
    )
}

/// §Perf batched-vs-looped head-to-head line
/// (`benches/sim_hotpath.rs`): the batch engine amortizes per-layer
/// cost tables across images, so it should at least modestly beat N
/// independent simulations.
pub fn batch_speedup_line(looped_ns: f64, batched_ns: f64) -> String {
    let ratio = looped_ns / batched_ns.max(1e-9);
    format!(
        "  -> batched engine {:.2}x looped per-image throughput \
         (target >= 1.1x: {})",
        ratio,
        if ratio >= 1.1 { "MET" } else { "MISSED" }
    )
}

/// Per-shard predicted-vs-achieved balance table for
/// `batch-sim --shards N`: one row per shard with its image count,
/// planned (predicted-cost) load and achieved (simulated-cycle) load,
/// plus their load shares. Also printed on the divergence *error* path,
/// so a nonzero exit always comes with the numbers that caused it.
pub fn shard_balance_table(plan: &ShardPlan, achieved: &[f64]) -> String {
    let sizes = plan.shard_sizes();
    let pred_total: f64 = plan.loads.iter().sum::<f64>().max(1e-12);
    let ach_total: f64 = achieved.iter().sum::<f64>().max(1e-12);
    let mut s = format!(
        "shard plan ({}, {} shards):\n  {:<5} {:>6} {:>16} {:>7} {:>16} {:>7}\n",
        plan.policy.name(),
        plan.n_shards,
        "shard",
        "images",
        "predicted",
        "share",
        "achieved",
        "share",
    );
    for i in 0..plan.n_shards {
        s.push_str(&format!(
            "  {:<5} {:>6} {:>16.0} {:>6.1}% {:>16.0} {:>6.1}%\n",
            i,
            sizes[i],
            plan.loads[i],
            100.0 * plan.loads[i] / pred_total,
            achieved[i],
            100.0 * achieved[i] / ach_total,
        ));
    }
    let ach_max = achieved.iter().copied().fold(0.0, f64::max);
    let ach_mean = ach_total / plan.n_shards.max(1) as f64;
    s.push_str(&format!(
        "  max/mean: predicted {:.3}  achieved {:.3}",
        plan.imbalance(),
        ach_max / ach_mean.max(1e-12),
    ));
    s
}

/// Largest per-shard divergence between predicted and achieved load
/// *shares* (scale-free: predicted OU-op costs and achieved cycles are
/// in different units, but a faithful plan gives every shard the same
/// share of both). 0.0 = the plan's balance was achieved exactly.
pub fn shard_share_divergence(predicted: &[f64], achieved: &[f64]) -> f64 {
    assert_eq!(predicted.len(), achieved.len());
    let pt: f64 = predicted.iter().sum::<f64>().max(1e-12);
    let at: f64 = achieved.iter().sum::<f64>().max(1e-12);
    predicted
        .iter()
        .zip(achieved.iter())
        .map(|(p, a)| (p / pt - a / at).abs())
        .fold(0.0, f64::max)
}

/// Shard-plan JSON (predicted + achieved loads) for `results/`.
pub fn shard_plan_json(plan: &ShardPlan, achieved: &[f64]) -> Json {
    obj(vec![
        ("plan", plan.to_json()),
        ("achieved_loads", arr_f64(achieved)),
        (
            "share_divergence",
            shard_share_divergence(&plan.loads, achieved).into(),
        ),
    ])
}

/// Per-core placement table for the `place` subcommand: one row per
/// CIM core with its layer set, compute/transfer/stage cycle totals
/// and utilization against the bottleneck stage.
pub fn placement_table(plan: &PlacementPlan, n_images: usize) -> String {
    let stages = plan.stage_times();
    let util = plan.utilization();
    let mut s = format!(
        "placement ({}, {} cores):\n  {:<5} {:<14} {:>16} {:>14} {:>16} {:>7}\n",
        plan.method,
        plan.n_cores,
        "core",
        "layers",
        "compute",
        "transfer",
        "stage",
        "util",
    );
    for c in 0..plan.n_cores {
        let layers: Vec<String> = plan
            .assignment
            .iter()
            .enumerate()
            .filter(|&(_, &a)| a == c)
            .map(|(l, _)| l.to_string())
            .collect();
        s.push_str(&format!(
            "  {:<5} {:<14} {:>16.0} {:>14.1} {:>16.1} {:>6.1}%\n",
            c,
            if layers.is_empty() { "-".to_string() } else { layers.join(",") },
            plan.compute[c],
            plan.transfer[c],
            stages[c],
            util[c] * 100.0,
        ));
    }
    s.push_str(&format!(
        "  max stage {:.0}  total transfer {:.1}  pipeline makespan {:.0} \
         ({} images)",
        plan.max_stage_time(),
        plan.total_transfer_cycles(),
        plan.pipeline_makespan(n_images),
        n_images,
    ));
    s
}

/// Placement JSON artifact (the `place` subcommand, under `results/`):
/// the plan with its per-core breakdown plus the pipelined batch
/// makespan and its speedup over the non-pipelined single-core total.
pub fn placement_json(
    plan: &PlacementPlan,
    n_images: usize,
    single_core_cycles: f64,
) -> Json {
    let makespan = plan.pipeline_makespan(n_images);
    obj(vec![
        ("plan", plan.to_json()),
        ("n_images", n_images.into()),
        ("pipeline_makespan_cycles", makespan.into()),
        ("single_core_cycles", single_core_cycles.into()),
        (
            "pipeline_speedup",
            (single_core_cycles / makespan.max(1e-12)).into(),
        ),
    ])
}

/// One line per pool worker for the `serve` subcommand.
pub fn worker_utilization_lines(stats: &[WorkerStats]) -> String {
    let mut s = String::new();
    for w in stats {
        s.push_str(&format!(
            "[serve] worker {}: {} requests ({} failed), {} batches \
             ({} padded slots, {} retried, {} requeued away), \
             outstanding {} cycles{}\n",
            w.worker,
            w.requests,
            w.failed_requests,
            w.batches,
            w.padded_slots,
            w.retried_batches,
            w.requeued_requests,
            w.outstanding_cost,
            if w.quarantined { " [QUARANTINED]" } else { "" },
        ));
    }
    let max = stats.iter().map(|w| w.requests).max().unwrap_or(0);
    let mean = stats.iter().map(|w| w.requests).sum::<u64>() as f64
        / stats.len().max(1) as f64;
    s.push_str(&format!(
        "[serve] worker request imbalance max/mean: {:.3}",
        max as f64 / mean.max(1e-12),
    ));
    s
}

/// Per-worker utilization/imbalance JSON for `results/`.
pub fn worker_utilization_json(stats: &[WorkerStats]) -> Json {
    let total: u64 = stats.iter().map(|w| w.requests).sum();
    let max = stats.iter().map(|w| w.requests).max().unwrap_or(0);
    let mean = total as f64 / stats.len().max(1) as f64;
    obj(vec![
        (
            "workers",
            Json::Arr(
                stats
                    .iter()
                    .map(|w| {
                        obj(vec![
                            ("worker", w.worker.into()),
                            ("requests", (w.requests as f64).into()),
                            ("failed_requests", (w.failed_requests as f64).into()),
                            ("batches", (w.batches as f64).into()),
                            ("padded_slots", (w.padded_slots as f64).into()),
                            ("retried_batches", (w.retried_batches as f64).into()),
                            (
                                "requeued_requests",
                                (w.requeued_requests as f64).into(),
                            ),
                            ("inflight", (w.inflight as f64).into()),
                            (
                                "outstanding_cost",
                                (w.outstanding_cost as f64).into(),
                            ),
                            ("quarantined", w.quarantined.into()),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("total_requests", (total as f64).into()),
        (
            "utilization_share_max",
            (max as f64 / (total as f64).max(1.0)).into(),
        ),
        ("imbalance_max_over_mean", (max as f64 / mean.max(1e-12)).into()),
    ])
}

/// §DSE parallel-sweep head-to-head line (`benches/dse_sweep.rs`): the
/// grid fan-out over the thread pool vs the same grid single-threaded.
pub fn sweep_speedup_line(single_ns: f64, parallel_ns: f64) -> String {
    let ratio = single_ns / parallel_ns.max(1e-9);
    format!(
        "  -> parallel sweep {:.2}x single-thread throughput \
         (target >= 2x: {})",
        ratio,
        if ratio >= 2.0 { "MET" } else { "MISSED" }
    )
}

/// §V-C speedup row.
pub fn speedup_line(dataset: &str, cmp: &Comparison, paper: f64) -> String {
    format!(
        "{:<10} cycles naive {:>14.0}  pattern {:>14.0}  speedup {:.2}x (paper {:.2}x)",
        dataset,
        cmp.baseline.total_cycles(),
        cmp.ours.total_cycles(),
        cmp.speedup(),
        paper,
    )
}

/// Pool metrics in Prometheus-style text exposition format — the body
/// of the HTTP front door's `GET /metrics`, also usable by any CLI
/// path that wants a scrape-ready dump. One `rram_*` line per counter,
/// per-worker series labeled `{worker="i"}`; every value is a plain
/// number (the snapshot already flattened empty-sample NaNs to 0).
pub fn metrics_export_text(m: &MetricsSnapshot, workers: &[WorkerStats]) -> String {
    let mut s = String::new();
    let mut counter = |name: &str, v: u64| {
        s.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
    };
    counter("rram_requests_total", m.requests);
    counter("rram_failed_requests_total", m.failed_requests);
    counter("rram_batches_total", m.batches);
    counter("rram_padded_slots_total", m.padded_slots);
    counter("rram_retried_batches_total", m.retried_batches);
    counter("rram_requeued_requests_total", m.requeued_requests);
    counter("rram_deadline_expired_total", m.deadline_expired);
    counter("rram_rejected_overload_total", m.rejected_overload);
    counter("rram_quarantine_events_total", m.quarantine_events);
    // process-wide logical counters from the pure paths (store and DSE
    // cache traffic accumulate in crate::obs::counters)
    let cache = crate::obs::counters::snapshot();
    counter("rram_store_hits_total", cache.store_hits);
    counter("rram_store_misses_total", cache.store_misses);
    counter("rram_dse_cache_hits_total", cache.dse_cache_hits);
    counter("rram_dse_cache_misses_total", cache.dse_cache_misses);
    s.push_str(&format!(
        "# TYPE rram_alarm_tripped gauge\nrram_alarm_tripped {}\n",
        u64::from(m.alarm_tripped)
    ));
    s.push_str(&format!(
        "# TYPE rram_latency_us summary\n\
         rram_latency_us_count {}\n\
         rram_latency_us_mean {}\n\
         rram_latency_us{{quantile=\"0.5\"}} {}\n\
         rram_latency_us{{quantile=\"0.99\"}} {}\n\
         rram_latency_us_max {}\n",
        m.latency_count,
        m.latency_mean_us,
        m.latency_p50_us,
        m.latency_p99_us,
        m.latency_max_us,
    ));
    // fixed-bucket histograms (cumulative, Prometheus convention)
    s.push_str("# TYPE rram_latency_us_hist histogram\n");
    for (le, c) in &m.latency_buckets {
        s.push_str(&format!(
            "rram_latency_us_hist_bucket{{le=\"{}\"}} {c}\n",
            le_label(*le)
        ));
    }
    s.push_str(&format!(
        "rram_latency_us_hist_sum {}\nrram_latency_us_hist_count {}\n",
        m.latency_sum_us, m.latency_count
    ));
    s.push_str("# TYPE rram_batch_fill histogram\n");
    let batch_count =
        m.batch_fill_buckets.last().map(|&(_, c)| c).unwrap_or(0);
    for (le, c) in &m.batch_fill_buckets {
        s.push_str(&format!(
            "rram_batch_fill_bucket{{le=\"{}\"}} {c}\n",
            le_label(*le)
        ));
    }
    s.push_str(&format!("rram_batch_fill_count {batch_count}\n"));
    s.push_str("# TYPE rram_worker_requests_total counter\n");
    for w in workers {
        s.push_str(&format!(
            "rram_worker_requests_total{{worker=\"{}\"}} {}\n",
            w.worker, w.requests
        ));
    }
    s.push_str("# TYPE rram_worker_inflight gauge\n");
    for w in workers {
        s.push_str(&format!(
            "rram_worker_inflight{{worker=\"{}\"}} {}\n",
            w.worker, w.inflight
        ));
    }
    s.push_str("# TYPE rram_worker_outstanding_cycles gauge\n");
    for w in workers {
        s.push_str(&format!(
            "rram_worker_outstanding_cycles{{worker=\"{}\"}} {}\n",
            w.worker, w.outstanding_cost
        ));
    }
    s.push_str("# TYPE rram_worker_quarantined gauge\n");
    for w in workers {
        s.push_str(&format!(
            "rram_worker_quarantined{{worker=\"{}\"}} {}\n",
            w.worker,
            u64::from(w.quarantined)
        ));
    }
    s
}

/// Prometheus `le` label for a bucket bound: integral bounds print
/// without decimals, the overflow bucket as `+Inf`.
fn le_label(le: f64) -> String {
    if le.is_infinite() {
        "+Inf".to_string()
    } else if le.fract() == 0.0 && le >= 0.0 && le < 9_007_199_254_740_992.0 {
        format!("{}", le as u64)
    } else {
        format!("{le}")
    }
}

/// Cumulative histogram as JSON: `{"buckets": [{"le", "count"}...],
/// "sum": f64}` with the same `le` labels as the text exposition.
fn hist_json(buckets: &[(f64, u64)], sum: f64) -> Json {
    let arr: Vec<Json> = buckets
        .iter()
        .map(|&(le, c)| {
            obj(vec![
                ("count", (c as f64).into()),
                ("le", le_label(le).into()),
            ])
        })
        .collect();
    obj(vec![("buckets", Json::Arr(arr)), ("sum", sum.into())])
}

/// The same pool view as [`metrics_export_text`], as a JSON document
/// (`GET /metrics?format=json`): the merged pool counters plus the
/// per-worker utilization block.
pub fn metrics_export_json(m: &MetricsSnapshot, workers: &[WorkerStats]) -> Json {
    obj(vec![
        (
            "pool",
            obj(vec![
                ("requests", (m.requests as f64).into()),
                ("failed_requests", (m.failed_requests as f64).into()),
                ("batches", (m.batches as f64).into()),
                ("padded_slots", (m.padded_slots as f64).into()),
                ("retried_batches", (m.retried_batches as f64).into()),
                ("requeued_requests", (m.requeued_requests as f64).into()),
                ("deadline_expired", (m.deadline_expired as f64).into()),
                ("rejected_overload", (m.rejected_overload as f64).into()),
                ("quarantine_events", (m.quarantine_events as f64).into()),
                ("alarm_threshold", (m.alarm_threshold as f64).into()),
                ("alarm_tripped", m.alarm_tripped.into()),
                ("latency_count", (m.latency_count as f64).into()),
                ("latency_mean_us", m.latency_mean_us.into()),
                ("latency_p50_us", m.latency_p50_us.into()),
                ("latency_p99_us", m.latency_p99_us.into()),
                ("latency_max_us", m.latency_max_us.into()),
                (
                    "latency_hist",
                    hist_json(&m.latency_buckets, m.latency_sum_us),
                ),
                ("batch_fill_hist", hist_json(&m.batch_fill_buckets, 0.0)),
            ]),
        ),
        ("cache", {
            let c = crate::obs::counters::snapshot();
            obj(vec![
                ("dse_cache_hits", (c.dse_cache_hits as f64).into()),
                ("dse_cache_misses", (c.dse_cache_misses as f64).into()),
                ("store_hits", (c.store_hits as f64).into()),
                ("store_misses", (c.store_misses as f64).into()),
            ])
        }),
        ("workers", worker_utilization_json(workers)),
    ])
}

/// Write a JSON report under `results/`, creating the directory.
pub fn write_json(path_under_results: &str, j: &Json) -> std::io::Result<()> {
    write_text(path_under_results, &j.to_string_pretty())
}

/// Write a text artifact (CSV, tables) under `results/`, creating the
/// directory — nested paths (e.g. `paper/fig7_exact.json`) get their
/// parent directories created too.
pub fn write_text(path_under_results: &str, text: &str) -> std::io::Result<()> {
    let path = Path::new("results").join(path_under_results);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;

    #[test]
    fn table1_contains_constants() {
        let s = table1(&HardwareConfig::default());
        assert!(s.contains("1.67"));
        assert!(s.contains("0.0182"));
        assert!(s.contains("9x8"));
        assert!(s.contains("4.8"));
    }

    #[test]
    fn fig7_math() {
        let r = Fig7Row {
            dataset: "cifar10".into(),
            naive_crossbars: 467,
            pattern_crossbars: 100,
            kmeans_crossbars: 430,
            ou_sparse_crossbars: 200,
            theoretical_best: 7.16,
            paper_efficiency: 4.67,
        };
        assert!((r.efficiency() - 4.67).abs() < 0.01);
        assert!((r.saved_fraction() - 0.7858).abs() < 0.001);
        let j = r.to_json();
        assert_eq!(j.get("naive_crossbars").as_usize(), Some(467));
        assert!(r.line().contains("4.67x"));
    }

    #[test]
    fn batch_lines_format() {
        use crate::sim::{BatchSimResult, LayerSimResult, NetworkSimResult};
        let img = NetworkSimResult {
            scheme: "pattern".into(),
            network: "t".into(),
            layers: vec![LayerSimResult {
                layer_idx: 0,
                ou_ops: 100.0,
                skipped_ou_ops: 0.0,
                cycles: 100.0,
                energy: EnergyLedger { adc_pj: 1.0, dac_pj: 0.0, rram_pj: 0.0 },
                n_crossbars: 1,
            }],
        };
        let b = BatchSimResult {
            scheme: "pattern".into(),
            network: "t".into(),
            per_image: vec![img.clone(), img],
        };
        let s = batch_line(&b);
        assert!(s.contains("batch of"), "{s}");
        assert!(s.contains("200"), "{s}");
        let sp = batch_speedup_line(220.0, 100.0);
        assert!(sp.contains("2.20x"), "{sp}");
        assert!(sp.contains("MET"), "{sp}");
        let sp = batch_speedup_line(100.0, 100.0);
        assert!(sp.contains("MISSED"), "{sp}");
    }

    #[test]
    fn sweep_line_formats_ratio_and_verdict() {
        let s = sweep_speedup_line(1000.0, 400.0);
        assert!(s.contains("2.50x"), "{s}");
        assert!(s.contains("MET"), "{s}");
        let s = sweep_speedup_line(300.0, 200.0);
        assert!(s.contains("1.50x"), "{s}");
        assert!(s.contains("MISSED"), "{s}");
    }

    #[test]
    fn engine_line_formats_ratio_and_verdict() {
        let s = engine_speedup_line(1000.0, 100.0);
        assert!(s.contains("10.0x"), "{s}");
        assert!(s.contains("MET"), "{s}");
        let s = engine_speedup_line(300.0, 100.0);
        assert!(s.contains("3.0x"), "{s}");
        assert!(s.contains("MISSED"), "{s}");
    }

    #[test]
    fn shard_table_and_divergence() {
        let plan = ShardPlan::cost_balanced(&[6.0, 4.0, 3.0, 3.0], 2);
        let achieved = plan.loads_with(&[6.6, 4.4, 3.3, 3.3]);
        let s = shard_balance_table(&plan, &achieved);
        assert!(s.contains("shard plan (cost, 2 shards)"), "{s}");
        assert!(s.contains("max/mean"), "{s}");
        // achieved is a uniform 1.1x scale of predicted: shares match
        let d = shard_share_divergence(&plan.loads, &achieved);
        assert!(d < 1e-12, "divergence {d}");
        // skewing one shard shows up as a share gap
        let skew = vec![achieved[0] * 2.0, achieved[1]];
        let d = shard_share_divergence(&plan.loads, &skew);
        assert!(d > 0.1, "divergence {d}");
        let j = shard_plan_json(&plan, &achieved);
        assert!(j.get("share_divergence").as_f64().unwrap() < 1e-12);
        assert_eq!(
            j.get("plan").get("n_shards").as_usize(),
            Some(2)
        );
    }

    #[test]
    fn placement_emitters() {
        use crate::sim::placement::{plan, PlacementProblem};
        let p = PlacementProblem {
            layer_cycles: vec![10.0, 10.0, 1.0, 1.0],
            transfer_bytes: vec![1.0, 1.0, 1.0],
            n_cores: 2,
            noc_bandwidth: 1000.0,
            noc_hop_latency: 0.0,
        };
        let best = plan(&p);
        let s = placement_table(&best, 8);
        assert!(s.contains("placement (greedy-lpt, 2 cores)"), "{s}");
        assert!(s.contains("max stage"), "{s}");
        assert!(s.contains("pipeline makespan"), "{s}");
        let j = placement_json(&best, 8, 22.0);
        assert_eq!(j.get("n_images").as_usize(), Some(8));
        assert!(j.get("pipeline_speedup").as_f64().unwrap() > 1.0);
        assert_eq!(j.get("plan").get("n_cores").as_usize(), Some(2));
        assert_eq!(
            j.get("plan").get("utilization").as_arr().map(|a| a.len()),
            Some(2)
        );
        // round-trips through the parser
        assert_eq!(Json::parse(&j.to_string_compact()).unwrap(), j);
    }

    #[test]
    fn worker_utilization_emitters() {
        let stats = vec![
            WorkerStats {
                worker: 0,
                requests: 6,
                failed_requests: 0,
                batches: 3,
                padded_slots: 2,
                retried_batches: 1,
                requeued_requests: 0,
                inflight: 0,
                outstanding_cost: 0,
                quarantined: false,
            },
            WorkerStats {
                worker: 1,
                requests: 2,
                failed_requests: 2,
                batches: 2,
                padded_slots: 0,
                retried_batches: 0,
                requeued_requests: 3,
                inflight: 1,
                outstanding_cost: 500,
                quarantined: true,
            },
        ];
        let lines = worker_utilization_lines(&stats);
        assert!(lines.contains("worker 0: 6 requests"), "{lines}");
        assert!(lines.contains("3 requeued away"), "{lines}");
        assert!(lines.contains("QUARANTINED"), "{lines}");
        assert!(lines.contains("imbalance max/mean: 1.500"), "{lines}");
        let j = worker_utilization_json(&stats);
        assert!(
            (j.get("workers").idx(1).get("requeued_requests").as_f64().unwrap()
                - 3.0)
                .abs()
                < 1e-12
        );
        assert_eq!(
            j.get("workers").as_arr().map(|a| a.len()),
            Some(2)
        );
        assert!((j.get("total_requests").as_f64().unwrap() - 8.0).abs() < 1e-12);
        assert!(
            (j.get("imbalance_max_over_mean").as_f64().unwrap() - 1.5).abs()
                < 1e-12
        );
        assert_eq!(
            j.get("workers").idx(1).get("quarantined").as_bool(),
            Some(true)
        );
    }

    #[test]
    fn metrics_export_formats() {
        let m = MetricsSnapshot {
            requests: 10,
            failed_requests: 2,
            batches: 4,
            padded_slots: 1,
            retried_batches: 1,
            requeued_requests: 0,
            deadline_expired: 1,
            rejected_overload: 1,
            quarantine_events: 1,
            alarm_threshold: 0,
            alarm_tripped: false,
            latency_count: 8,
            latency_mean_us: 250.0,
            latency_p50_us: 200.0,
            latency_p99_us: 900.0,
            latency_max_us: 1000.0,
            latency_buckets: vec![(250.0, 5), (500.0, 7), (f64::INFINITY, 8)],
            latency_sum_us: 2000.0,
            batch_fill_buckets: vec![(1.0, 2), (4.0, 4), (f64::INFINITY, 4)],
        };
        let workers = vec![WorkerStats {
            worker: 0,
            requests: 10,
            failed_requests: 2,
            batches: 4,
            padded_slots: 1,
            retried_batches: 1,
            requeued_requests: 0,
            inflight: 0,
            outstanding_cost: 42,
            quarantined: true,
        }];
        let t = metrics_export_text(&m, &workers);
        assert!(t.contains("rram_requests_total 10"), "{t}");
        assert!(t.contains("rram_deadline_expired_total 1"), "{t}");
        assert!(t.contains("rram_quarantine_events_total 1"), "{t}");
        assert!(t.contains("rram_store_hits_total "), "{t}");
        assert!(t.contains("rram_dse_cache_misses_total "), "{t}");
        assert!(
            t.contains("rram_latency_us{quantile=\"0.99\"} 900"),
            "{t}"
        );
        assert!(
            t.contains("rram_latency_us_hist_bucket{le=\"250\"} 5"),
            "{t}"
        );
        assert!(
            t.contains("rram_latency_us_hist_bucket{le=\"+Inf\"} 8"),
            "{t}"
        );
        assert!(t.contains("rram_latency_us_hist_sum 2000"), "{t}");
        assert!(t.contains("rram_batch_fill_bucket{le=\"4\"} 4"), "{t}");
        assert!(t.contains("rram_batch_fill_count 4"), "{t}");
        assert!(
            t.contains("rram_worker_quarantined{worker=\"0\"} 1"),
            "{t}"
        );
        for line in t.lines() {
            assert!(
                line.starts_with('#') || line.starts_with("rram_"),
                "unexpected exposition line: {line}"
            );
        }
        let j = metrics_export_json(&m, &workers);
        assert_eq!(j.get("pool").get("requests").as_f64(), Some(10.0));
        assert_eq!(j.get("pool").get("latency_p99_us").as_f64(), Some(900.0));
        assert_eq!(
            j.get("pool").get("quarantine_events").as_f64(),
            Some(1.0)
        );
        let hist = j.get("pool").get("latency_hist");
        assert_eq!(hist.get("sum").as_f64(), Some(2000.0));
        assert_eq!(
            hist.get("buckets").idx(2).get("le").as_str(),
            Some("+Inf")
        );
        assert_eq!(hist.get("buckets").idx(0).get("count").as_f64(), Some(5.0));
        assert!(j.get("cache").get("store_hits").as_f64().is_some());
        assert_eq!(
            j.get("workers").get("workers").idx(0).get("outstanding_cost").as_f64(),
            Some(42.0)
        );
        // round-trips through the parser
        assert_eq!(Json::parse(&j.to_string_compact()).unwrap(), j);
    }

    #[test]
    fn table2_row_speedup_and_json() {
        let r = Table2Row {
            dataset: "cifar10".into(),
            sparsity: 0.8603,
            paper_sparsity: 0.8603,
            patterns_per_layer: vec![2, 2, 8],
            paper_patterns_per_layer: vec![2, 2, 8],
            total_patterns: 12,
            all_zero_ratio: 0.41,
            paper_all_zero_ratio: 0.409,
            top1: "92.63%".into(),
            top5: "/".into(),
            naive_cycles: 1200.0,
            pattern_cycles: 400.0,
            paper_speedup: 1.35,
        };
        assert!((r.speedup() - 3.0).abs() < 1e-12);
        let j = r.to_json();
        assert_eq!(j.get("naive_cycles").as_f64(), Some(1200.0));
        assert!((j.get("speedup").as_f64().unwrap() - 3.0).abs() < 1e-12);
        assert_eq!(
            j.get("patterns_per_layer").as_arr().map(|a| a.len()),
            Some(3)
        );
        assert!(r.line().contains("3.00x"), "{}", r.line());
        assert!(r.line().contains("paper 1.35x"), "{}", r.line());
    }

    #[test]
    fn fig8_normalization() {
        let r = Fig8Row {
            dataset: "cifar10".into(),
            baseline: EnergyLedger { adc_pj: 80.0, dac_pj: 2.0, rram_pj: 18.0 },
            ours: EnergyLedger { adc_pj: 40.0, dac_pj: 0.5, rram_pj: 6.5 },
            paper_efficiency: 2.13,
        };
        assert!((r.efficiency() - 100.0 / 47.0).abs() < 1e-9);
        let j = r.to_json();
        assert!((j.get("baseline_adc").as_f64().unwrap() - 0.8).abs() < 1e-12);
        assert!((j.get("ours_total_norm").as_f64().unwrap() - 0.47).abs() < 1e-12);
    }
}
