//! Exact-trace paper-artifact pipeline (ISSUE-5 tentpole).
//!
//! The paper's headline artifacts — Fig. 7 (crossbar area efficiency),
//! Fig. 8 (normalized energy) and Table II (+ the §V-C speedup) — were
//! historically reproduced from 64 sampled output positions per layer.
//! The trace-aggregated engine made exact mode affordable, so this
//! layer runs every figure in **both** trace modes over the Table-II
//! synthetic VGG16 datasets and records the sampled-vs-exact deltas:
//!
//! ```text
//!   ALL_PROFILES × TraceMode::{Sampled(n), Exact}
//!        │ compute_dataset_rows — generate weights, map all four
//!        │   schemes, simulate naive + pattern (shared by the CLI,
//!        │   `cargo bench` figure benches and `rram-accel report`)
//!        ▼
//!   PaperArtifacts — one JSON bundle per dataset, emitted as
//!        │   results/paper/{fig7,fig8,table2}_{sampled,exact}.json
//!        │   (an on-disk ArtifactCache makes repeated runs cheap and
//!        │   bit-exact with fresh ones)
//!        ▼
//!   delta_report — per-dataset, per-scheme relative deltas
//!        |sampled − exact| / |exact| with tolerance bands, emitted as
//!        results/paper/delta_report.json
//! ```
//!
//! Determinism contract (pinned by `tests/paper_artifacts.rs`, the
//! tier-2 conformance suite): every emitted byte is a pure function of
//! `(profiles, seed, mode)` — independent of thread count and of
//! whether results came from the cache — and structural metrics
//! (crossbar counts, sparsity) must not move between modes at all,
//! while trace-dependent metrics (cycles, energy, speedup) must stay
//! inside the declared tolerance bands.

use std::path::{Path, PathBuf};

use crate::config::{HardwareConfig, SimConfig};
use crate::mapping::{
    kmeans::KmeansMapping, naive::NaiveMapping, ou_sparse::OuSparseMapping,
    pattern::PatternMapping, MappingScheme,
};
use crate::pruning::synthetic::DatasetProfile;
use crate::sim::{self, Comparison};
use crate::util::fnv1a;
use crate::util::json::{obj, Json};
use crate::xbar::CellGeometry;

use super::{write_json, Fig7Row, Fig8Row, Table2Row};

/// Published reference numbers for one dataset row (paper Fig. 7,
/// Fig. 8 and Table 2 / §V-C).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PaperRef {
    pub area_efficiency: f64,
    pub energy_efficiency: f64,
    pub speedup: f64,
}

/// Paper reference values by dataset name — the single source the CLI,
/// the figure benches and `rram-accel report` all print against.
pub fn paper_reference(dataset: &str) -> Option<PaperRef> {
    match dataset {
        "cifar10" => Some(PaperRef {
            area_efficiency: 4.67,
            energy_efficiency: 2.13,
            speedup: 1.35,
        }),
        "cifar100" => Some(PaperRef {
            area_efficiency: 5.20,
            energy_efficiency: 2.15,
            speedup: 1.15,
        }),
        "imagenet" => Some(PaperRef {
            area_efficiency: 4.16,
            energy_efficiency: 1.98,
            speedup: 1.17,
        }),
        _ => None,
    }
}

/// The paper's area-efficiency band: the published per-dataset factors
/// span 4.16x (imagenet) to 5.20x (cifar100). The reproduction's
/// ordering/band invariants are asserted against this in exact mode.
pub const PAPER_AREA_BAND: (f64, f64) = (4.16, 5.20);

/// Trace fidelity of one artifact run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceMode {
    /// `n` sampled output positions per layer, scaled to the full map.
    Sampled(usize),
    /// Every output position traced — no sampling scale.
    Exact,
}

impl TraceMode {
    pub fn name(&self) -> &'static str {
        match self {
            TraceMode::Sampled(_) => "sampled",
            TraceMode::Exact => "exact",
        }
    }

    /// The [`SimConfig`] this mode simulates under (activation-model
    /// defaults untouched, so both modes share the same trace seed).
    pub fn sim_config(&self) -> SimConfig {
        match self {
            TraceMode::Sampled(n) => SimConfig::sampled(*n),
            TraceMode::Exact => SimConfig::exact(),
        }
    }

    fn sample_positions_json(&self) -> Json {
        match self {
            TraceMode::Sampled(n) => (*n).into(),
            TraceMode::Exact => Json::Null,
        }
    }
}

/// One artifact run's configuration: weight seed, trace mode, worker
/// threads. The hardware is always the paper's Table I config — the
/// artifacts reproduce the paper, not an arbitrary design point.
#[derive(Debug, Clone, Copy)]
pub struct ArtifactConfig {
    pub seed: u64,
    pub mode: TraceMode,
    /// Worker threads for mapping/simulation. Never part of any
    /// artifact identity: results are thread-invariant.
    pub threads: usize,
}

/// The typed rows of one dataset's artifacts, plus the underlying
/// naive/pattern comparison for consumers that need the full
/// simulation results (`rram-accel report`, `benches/fig8_energy.rs`).
pub struct DatasetRows {
    pub dataset: String,
    pub fig7: Fig7Row,
    pub fig8: Fig8Row,
    pub table2: Table2Row,
    pub comparison: Comparison,
}

impl DatasetRows {
    /// Collapse into the JSON bundle the pipeline caches and emits.
    pub fn to_artifact(&self) -> DatasetArtifact {
        DatasetArtifact {
            dataset: self.dataset.clone(),
            fig7: self.fig7.to_json(),
            fig8: self.fig8.to_json(),
            table2: self.table2.to_json(),
        }
    }
}

/// Compute one dataset's paper rows from scratch: Table-II-calibrated
/// weights, all four mapping schemes for the Fig. 7 series, and the
/// naive/pattern simulation under the run's trace mode. Pure function
/// of `(profile, cfg.seed, cfg.mode)`; `cfg.threads` only changes how
/// fast it runs.
///
/// The mappings depend only on `(profile, seed)`, so a two-mode
/// `artifacts` run recomputes them once per mode — a deliberate
/// simplicity/size tradeoff: the per-(dataset, mode) [`ArtifactCache`]
/// entry makes every repeat run free, which is where the time would
/// otherwise go.
pub fn compute_dataset_rows(
    profile: &DatasetProfile,
    cfg: &ArtifactConfig,
) -> DatasetRows {
    let hw = HardwareConfig::default();
    let geom = CellGeometry::from_hw(&hw);
    let threads = cfg.threads.max(1);
    let nw = profile.generate(cfg.seed);
    let spec = nw.spec.clone();
    let stats = nw.stats();
    let naive = NaiveMapping.map_network(&nw, &geom, threads);
    let ours = PatternMapping.map_network(&nw, &geom, threads);
    let km = KmeansMapping::default().map_network(&nw, &geom, threads);
    let sre = OuSparseMapping.map_network(&nw, &geom, threads);
    // Paper artifacts from an invalid mapping would be silently wrong
    // numbers — fail loudly instead (this gate used to live in the
    // Fig. 7 bench; it now guards every consumer of the shared path).
    for (name, mapped) in
        [("naive", &naive), ("pattern", &ours), ("kmeans", &km), ("ou_sparse", &sre)]
    {
        if let Err(e) = mapped.validate() {
            panic!(
                "{name} mapping violated invariants on {}: {e}",
                profile.name
            );
        }
    }
    let sim_cfg = cfg.mode.sim_config();
    let base = sim::simulate_network(&naive, &spec, &hw, &sim_cfg, threads);
    let mine = sim::simulate_network(&ours, &spec, &hw, &sim_cfg, threads);
    let paper = paper_reference(profile.name).unwrap_or(PaperRef {
        area_efficiency: 0.0,
        energy_efficiency: 0.0,
        speedup: 0.0,
    });

    let fig7 = Fig7Row {
        dataset: profile.name.to_string(),
        naive_crossbars: naive.total_crossbars(),
        pattern_crossbars: ours.total_crossbars(),
        kmeans_crossbars: km.total_crossbars(),
        ou_sparse_crossbars: sre.total_crossbars(),
        theoretical_best: 1.0 / (1.0 - profile.sparsity),
        paper_efficiency: paper.area_efficiency,
    };
    let fig8 = Fig8Row {
        dataset: profile.name.to_string(),
        baseline: base.total_energy(),
        ours: mine.total_energy(),
        paper_efficiency: paper.energy_efficiency,
    };
    let table2 = Table2Row {
        dataset: profile.name.to_string(),
        sparsity: stats.sparsity,
        paper_sparsity: profile.sparsity,
        patterns_per_layer: stats.patterns_per_layer.clone(),
        paper_patterns_per_layer: profile.patterns_per_layer.to_vec(),
        total_patterns: stats.total_patterns,
        all_zero_ratio: stats.all_zero_kernel_ratio,
        paper_all_zero_ratio: profile.all_zero_ratio,
        top1: profile.top1.to_string(),
        top5: profile.top5.to_string(),
        naive_cycles: base.total_cycles(),
        pattern_cycles: mine.total_cycles(),
        paper_speedup: paper.speedup,
    };
    DatasetRows {
        dataset: profile.name.to_string(),
        fig7,
        fig8,
        table2,
        comparison: Comparison { baseline: base, ours: mine },
    }
}

/// One dataset's artifact bundle as canonical JSON. Both the fresh and
/// the cached path flow through this representation, so cached and
/// fresh runs emit identical bytes by construction.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetArtifact {
    pub dataset: String,
    pub fig7: Json,
    pub fig8: Json,
    pub table2: Json,
}

impl DatasetArtifact {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("dataset", self.dataset.as_str().into()),
            ("fig7", self.fig7.clone()),
            ("fig8", self.fig8.clone()),
            ("table2", self.table2.clone()),
        ])
    }

    /// Inverse of [`DatasetArtifact::to_json`]; `None` on any missing
    /// section (a corrupt cache entry falls back to a fresh compute).
    pub fn from_json(j: &Json) -> Option<DatasetArtifact> {
        let dataset = j.get("dataset").as_str()?.to_string();
        let (fig7, fig8, table2) =
            (j.get("fig7"), j.get("fig8"), j.get("table2"));
        if fig7.as_obj().is_none()
            || fig8.as_obj().is_none()
            || table2.as_obj().is_none()
        {
            return None;
        }
        Some(DatasetArtifact {
            dataset,
            fig7: fig7.clone(),
            fig8: fig8.clone(),
            table2: table2.clone(),
        })
    }

    /// Numeric field of one section (`"fig7"` / `"fig8"` / `"table2"`).
    pub fn metric(&self, section: &str, key: &str) -> Option<f64> {
        let s = match section {
            "fig7" => &self.fig7,
            "fig8" => &self.fig8,
            "table2" => &self.table2,
            _ => return None,
        };
        s.get(key).as_f64()
    }
}

/// Every paper artifact of one run: per-dataset bundles under one
/// trace mode, plus runtime bookkeeping (cache hits are deliberately
/// absent from all emitted JSON).
pub struct PaperArtifacts {
    pub mode: TraceMode,
    pub seed: u64,
    pub datasets: Vec<DatasetArtifact>,
    /// Datasets served from the [`ArtifactCache`] this run.
    pub cache_hits: usize,
}

impl PaperArtifacts {
    /// Run the pipeline over `profiles` (cache first, compute on miss).
    pub fn generate(
        profiles: &[&DatasetProfile],
        cfg: &ArtifactConfig,
        cache: Option<&ArtifactCache>,
    ) -> PaperArtifacts {
        let mut datasets = Vec::with_capacity(profiles.len());
        let mut cache_hits = 0usize;
        for p in profiles {
            if let Some(c) = cache {
                if let Some(a) = c.load(p, cfg) {
                    cache_hits += 1;
                    datasets.push(a);
                    continue;
                }
            }
            let a = compute_dataset_rows(p, cfg).to_artifact();
            if let Some(c) = cache {
                if let Err(e) = c.store(p, cfg, &a) {
                    eprintln!(
                        "[artifacts] cache write failed for {}: {e} \
                         (continuing uncached)",
                        p.name
                    );
                }
            }
            datasets.push(a);
        }
        PaperArtifacts { mode: cfg.mode, seed: cfg.seed, datasets, cache_hits }
    }

    fn meta(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("mode", self.mode.name().into()),
            ("sample_positions", self.mode.sample_positions_json()),
            ("seed", (self.seed as usize).into()),
        ]
    }

    fn figure_json(&self, pick: fn(&DatasetArtifact) -> &Json) -> Json {
        let mut pairs = self.meta();
        pairs.push((
            "rows",
            Json::Arr(self.datasets.iter().map(|d| pick(d).clone()).collect()),
        ));
        obj(pairs)
    }

    pub fn fig7_json(&self) -> Json {
        self.figure_json(|d| &d.fig7)
    }

    pub fn fig8_json(&self) -> Json {
        self.figure_json(|d| &d.fig8)
    }

    pub fn table2_json(&self) -> Json {
        self.figure_json(|d| &d.table2)
    }

    pub fn dataset(&self, name: &str) -> Option<&DatasetArtifact> {
        self.datasets.iter().find(|d| d.dataset == name)
    }

    /// Write `{fig7,fig8,table2}_{mode}.json` under
    /// `results/<subdir>/`; returns the paths written (relative to
    /// `results/`).
    pub fn write(&self, subdir: &str) -> std::io::Result<Vec<String>> {
        let mode = self.mode.name();
        let files = [
            (format!("{subdir}/fig7_{mode}.json"), self.fig7_json()),
            (format!("{subdir}/fig8_{mode}.json"), self.fig8_json()),
            (format!("{subdir}/table2_{mode}.json"), self.table2_json()),
        ];
        let mut written = Vec::with_capacity(files.len());
        for (name, j) in files {
            write_json(&name, &j)?;
            written.push(name);
        }
        Ok(written)
    }
}

/// Content-hashed on-disk cache of per-dataset artifact bundles,
/// mirroring `dse::ResultCache`: the identity is the canonical string
/// of `(format version, profile contents + network spec, weight seed,
/// effective SimConfig, base HardwareConfig)`, stored alongside the
/// bundle and verified on load — editing a Table-II profile or a VGG16
/// layer list invalidates old entries without anyone remembering to
/// bump the format version. Thread count is deliberately absent —
/// results are thread-invariant.
///
/// Storage is the binary pack store ([`crate::store`]):
/// `paper.{pack,idx}` in the cache directory, payload = the bundle's
/// compact canonical JSON. The identity string (and therefore the key)
/// is unchanged from the per-file layout, so a pack miss falls back to
/// the matching legacy `{key:016x}.json` entry — read-only — verifies
/// it, and migrates it into the pack.
#[derive(Debug, Clone)]
pub struct ArtifactCache {
    dir: PathBuf,
    /// `None` when the pack could not be opened (unwritable dir):
    /// loads fall back to legacy JSON, stores report the failure.
    pack: Option<crate::store::PackStore>,
}

/// Bump when the artifact layout or the evaluation semantics change.
const ARTIFACT_CACHE_FORMAT: usize = 1;

/// Pack domain name: `results/paper_cache/paper.{pack,idx}`.
const ARTIFACT_PACK_DOMAIN: &str = "paper";

impl ArtifactCache {
    pub fn new<P: Into<PathBuf>>(dir: P) -> ArtifactCache {
        let dir: PathBuf = dir.into();
        let pack = match crate::store::PackStore::open(
            &dir.to_string_lossy(),
            ARTIFACT_PACK_DOMAIN,
        ) {
            Ok(p) => Some(p),
            Err(e) => {
                eprintln!(
                    "[artifacts] cache store unavailable: {e} \
                     (continuing uncached)"
                );
                None
            }
        };
        ArtifactCache { dir, pack }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Canonical identity of one profile's *contents*: every statistic
    /// the synthetic generator consumes plus the concrete layer list,
    /// so a profile edit can never serve a stale bundle.
    fn profile_identity(p: &DatasetProfile) -> String {
        let spec = p.network_spec();
        let layers: Vec<String> = spec
            .layers
            .iter()
            .map(|l| format!("{}x{}x{}", l.cout, l.cin, l.fmap))
            .collect();
        format!(
            "{}|sp{}|pat{:?}|zr{}|{}|{}|{}",
            p.name,
            p.sparsity,
            p.patterns_per_layer,
            p.all_zero_ratio,
            p.top1,
            p.top5,
            layers.join(","),
        )
    }

    fn identity(profile: &DatasetProfile, cfg: &ArtifactConfig) -> (u64, String) {
        let sim = cfg.mode.sim_config().to_json().to_string_compact();
        let hw = HardwareConfig::default().to_json().to_string_compact();
        let id = format!(
            "v{ARTIFACT_CACHE_FORMAT}|{}|seed{}|{sim}|{hw}",
            Self::profile_identity(profile),
            cfg.seed
        );
        (fnv1a(&id), id)
    }

    fn path_for(&self, key: u64) -> PathBuf {
        self.dir.join(format!("{key:016x}.json"))
    }

    /// Load a profile's cached bundle, verifying the stored identity.
    /// Any miss, mismatch or parse failure returns `None`. Pack first;
    /// a miss falls back to the read-only legacy JSON entry (same key —
    /// the identity string is unchanged) and migrates a hit into the
    /// pack.
    pub fn load(
        &self,
        profile: &DatasetProfile,
        cfg: &ArtifactConfig,
    ) -> Option<DatasetArtifact> {
        let (key, id) = Self::identity(profile, cfg);
        if let Some(pack) = &self.pack {
            if let Some(rec) = pack.get(key) {
                if rec.id == id {
                    if let Some(a) = std::str::from_utf8(&rec.payload)
                        .ok()
                        .and_then(|t| Json::parse(t).ok())
                        .and_then(|j| DatasetArtifact::from_json(&j))
                    {
                        return Some(a);
                    }
                }
                // collision or corrupt payload: fall through
            }
        }
        let a = self.load_legacy(key, &id)?;
        if let Some(pack) = &self.pack {
            let _ = pack.put(
                key,
                &id,
                a.to_json().to_string_compact().as_bytes(),
            );
        }
        Some(a)
    }

    /// Read-only legacy path: the per-file JSON entry layout this cache
    /// wrote before the pack store.
    fn load_legacy(&self, key: u64, id: &str) -> Option<DatasetArtifact> {
        let text = std::fs::read_to_string(self.path_for(key)).ok()?;
        let j = Json::parse(&text).ok()?;
        if j.get("format").as_usize() != Some(ARTIFACT_CACHE_FORMAT)
            || j.get("identity").as_str() != Some(id)
        {
            return None; // collision or stale defaults: recompute
        }
        DatasetArtifact::from_json(j.get("artifact"))
    }

    /// Persist a profile's bundle into the pack. Write failures are
    /// returned, not fatal — the pipeline treats the cache as
    /// best-effort.
    pub fn store(
        &self,
        profile: &DatasetProfile,
        cfg: &ArtifactConfig,
        a: &DatasetArtifact,
    ) -> std::io::Result<()> {
        let (key, id) = Self::identity(profile, cfg);
        let pack = self.pack.as_ref().ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::Other,
                "artifact pack store unavailable",
            )
        })?;
        pack.put(key, &id, a.to_json().to_string_compact().as_bytes())
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::Other, e))
    }
}

/// Tolerance bands of the sampled-vs-exact delta report, as relative
/// deltas `|sampled − exact| / |exact|`.
#[derive(Debug, Clone, Copy)]
pub struct DeltaTolerances {
    /// Structural metrics — crossbar counts, area efficiency, sparsity
    /// — do not depend on the activation trace at all, so sampled and
    /// exact runs must agree exactly.
    pub structure: f64,
    /// Simulated cycle totals (trace-dependent through zero skipping).
    pub cycles: f64,
    /// Simulated energy totals and the derived energy efficiency.
    pub energy: f64,
    /// The naive/pattern speedup ratio.
    pub speedup: f64,
}

impl Default for DeltaTolerances {
    fn default() -> Self {
        // 64 sampled positions estimate per-layer skip fractions to a
        // few percent (binomial error ~ 1/sqrt(64)); 10% bands leave
        // headroom without masking a broken trace mode.
        DeltaTolerances { structure: 0.0, cycles: 0.10, energy: 0.10, speedup: 0.10 }
    }
}

impl DeltaTolerances {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("structure", self.structure.into()),
            ("cycles", self.cycles.into()),
            ("energy", self.energy.into()),
            ("speedup", self.speedup.into()),
        ])
    }
}

/// One compared metric of the delta report.
#[derive(Debug, Clone)]
pub struct DeltaEntry {
    pub dataset: String,
    pub figure: &'static str,
    pub metric: &'static str,
    /// Scheme the metric belongs to (`"-"` for scheme-free metrics
    /// like sparsity).
    pub scheme: &'static str,
    pub sampled: f64,
    pub exact: f64,
    pub rel_delta: f64,
    pub tolerance: f64,
}

impl DeltaEntry {
    pub fn within(&self) -> bool {
        self.rel_delta <= self.tolerance
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("dataset", self.dataset.as_str().into()),
            ("figure", self.figure.into()),
            ("metric", self.metric.into()),
            ("scheme", self.scheme.into()),
            ("sampled", self.sampled.into()),
            ("exact", self.exact.into()),
            ("rel_delta", self.rel_delta.into()),
            ("tolerance", self.tolerance.into()),
            ("within", self.within().into()),
        ])
    }
}

/// The machine-readable sampled-vs-exact comparison
/// (`results/paper/delta_report.json`).
pub struct DeltaReport {
    pub seed: u64,
    /// Sample count of the sampled side.
    pub sampled_positions: Option<usize>,
    pub tolerances: DeltaTolerances,
    pub entries: Vec<DeltaEntry>,
}

/// `(figure, json key, metric label, scheme, tolerance selector)` of
/// one compared metric.
type DeltaMetricSpec =
    (&'static str, &'static str, &'static str, &'static str, fn(&DeltaTolerances) -> f64);

/// The catalog of compared metrics.
fn delta_metrics() -> [DeltaMetricSpec; 12] {
    [
        ("fig7", "naive_crossbars", "crossbars", "naive", |t| t.structure),
        ("fig7", "pattern_crossbars", "crossbars", "pattern", |t| t.structure),
        ("fig7", "kmeans_crossbars", "crossbars", "kmeans", |t| t.structure),
        ("fig7", "ou_sparse_crossbars", "crossbars", "ou_sparse", |t| t.structure),
        ("fig7", "area_efficiency", "area_efficiency", "pattern", |t| t.structure),
        ("fig8", "baseline_total_pj", "energy_pj", "naive", |t| t.energy),
        ("fig8", "ours_total_pj", "energy_pj", "pattern", |t| t.energy),
        ("fig8", "energy_efficiency", "energy_efficiency", "pattern", |t| {
            t.energy
        }),
        ("table2", "naive_cycles", "cycles", "naive", |t| t.cycles),
        ("table2", "pattern_cycles", "cycles", "pattern", |t| t.cycles),
        ("table2", "speedup", "speedup", "pattern", |t| t.speedup),
        ("table2", "sparsity", "sparsity", "-", |t| t.structure),
    ]
}

/// Build the delta report from a sampled and an exact run over the
/// same datasets. Errors (rather than silently skipping) when the runs
/// have the wrong or swapped trace modes, were generated from
/// different weight seeds, cover different datasets, or an expected
/// metric is missing — a malformed comparison must not read as "all
/// deltas in band".
pub fn delta_report(
    sampled: &PaperArtifacts,
    exact: &PaperArtifacts,
    tol: &DeltaTolerances,
) -> Result<DeltaReport, String> {
    if !matches!(sampled.mode, TraceMode::Sampled(_)) {
        return Err("first run must be sampled-mode (runs swapped?)".into());
    }
    if exact.mode != TraceMode::Exact {
        return Err("second run must be exact-mode (runs swapped?)".into());
    }
    if sampled.seed != exact.seed {
        return Err(format!(
            "weight seed mismatch: sampled {} vs exact {} — the runs \
             simulate different synthetic networks",
            sampled.seed, exact.seed
        ));
    }
    if sampled.datasets.len() != exact.datasets.len() {
        return Err(format!(
            "dataset count mismatch: sampled {} vs exact {}",
            sampled.datasets.len(),
            exact.datasets.len()
        ));
    }
    let mut entries = Vec::new();
    for (s, e) in sampled.datasets.iter().zip(exact.datasets.iter()) {
        if s.dataset != e.dataset {
            return Err(format!(
                "dataset order mismatch: {} vs {}",
                s.dataset, e.dataset
            ));
        }
        for (figure, key, metric, scheme, pick_tol) in delta_metrics() {
            let sv = s.metric(figure, key).ok_or_else(|| {
                format!("{}: sampled {figure}.{key} missing", s.dataset)
            })?;
            let ev = e.metric(figure, key).ok_or_else(|| {
                format!("{}: exact {figure}.{key} missing", e.dataset)
            })?;
            let rel_delta = (sv - ev).abs() / ev.abs().max(1e-12);
            entries.push(DeltaEntry {
                dataset: s.dataset.clone(),
                figure,
                metric,
                scheme,
                sampled: sv,
                exact: ev,
                rel_delta,
                tolerance: pick_tol(tol),
            });
        }
    }
    let sampled_positions = match sampled.mode {
        TraceMode::Sampled(n) => Some(n),
        TraceMode::Exact => None,
    };
    Ok(DeltaReport {
        seed: sampled.seed,
        sampled_positions,
        tolerances: *tol,
        entries,
    })
}

impl DeltaReport {
    pub fn all_within(&self) -> bool {
        self.entries.iter().all(|e| e.within())
    }

    pub fn violations(&self) -> Vec<&DeltaEntry> {
        self.entries.iter().filter(|e| !e.within()).collect()
    }

    pub fn max_rel_delta(&self) -> f64 {
        self.entries.iter().map(|e| e.rel_delta).fold(0.0, f64::max)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("seed", (self.seed as usize).into()),
            (
                "sampled_positions",
                self.sampled_positions.map(Json::from).unwrap_or(Json::Null),
            ),
            ("tolerances", self.tolerances.to_json()),
            ("n_entries", self.entries.len().into()),
            ("n_violations", self.violations().len().into()),
            ("max_rel_delta", self.max_rel_delta().into()),
            ("all_within", self.all_within().into()),
            (
                "entries",
                Json::Arr(self.entries.iter().map(|e| e.to_json()).collect()),
            ),
        ])
    }

    /// Human summary: one line per dataset with its worst delta, plus
    /// one line per out-of-band entry.
    pub fn lines(&self) -> String {
        let mut s = format!(
            "sampled-vs-exact deltas: {} metrics, max rel delta {:.3e} ({})\n",
            self.entries.len(),
            self.max_rel_delta(),
            if self.all_within() {
                "all within tolerance"
            } else {
                "OUT OF BAND"
            },
        );
        let mut seen: Vec<&str> = Vec::new();
        for e in &self.entries {
            if !seen.contains(&e.dataset.as_str()) {
                seen.push(&e.dataset);
            }
        }
        for ds in seen {
            let worst = self
                .entries
                .iter()
                .filter(|e| e.dataset == ds)
                .max_by(|a, b| {
                    a.rel_delta
                        .partial_cmp(&b.rel_delta)
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .expect("dataset has entries");
            s.push_str(&format!(
                "  {:<10} worst {}/{} ({}): rel delta {:.3e} (tol {:.2})\n",
                ds,
                worst.figure,
                worst.metric,
                worst.scheme,
                worst.rel_delta,
                worst.tolerance,
            ));
        }
        for v in self.violations() {
            s.push_str(&format!(
                "  OUT OF BAND {} {}/{} ({}): sampled {} vs exact {} — rel \
                 delta {:.3e} > tol {:.2}\n",
                v.dataset,
                v.figure,
                v.metric,
                v.scheme,
                v.sampled,
                v.exact,
                v.rel_delta,
                v.tolerance,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bundle(dataset: &str, energy: f64, cycles: f64) -> DatasetArtifact {
        DatasetArtifact {
            dataset: dataset.into(),
            fig7: obj(vec![
                ("dataset", dataset.into()),
                ("naive_crossbars", 400.into()),
                ("pattern_crossbars", 100.into()),
                ("kmeans_crossbars", 380.into()),
                ("ou_sparse_crossbars", 200.into()),
                ("area_efficiency", Json::Num(4.0)),
            ]),
            fig8: obj(vec![
                ("baseline_total_pj", Json::Num(2.0 * energy)),
                ("ours_total_pj", Json::Num(energy)),
                ("energy_efficiency", Json::Num(2.0)),
            ]),
            table2: obj(vec![
                ("naive_cycles", Json::Num(2.0 * cycles)),
                ("pattern_cycles", Json::Num(cycles)),
                ("speedup", Json::Num(2.0)),
                ("sparsity", Json::Num(0.86)),
            ]),
        }
    }

    fn run(mode: TraceMode, bundles: Vec<DatasetArtifact>) -> PaperArtifacts {
        PaperArtifacts { mode, seed: 42, datasets: bundles, cache_hits: 0 }
    }

    #[test]
    fn paper_references_cover_all_profiles() {
        for name in ["cifar10", "cifar100", "imagenet"] {
            let r = paper_reference(name).expect(name);
            assert!(r.area_efficiency >= PAPER_AREA_BAND.0);
            assert!(r.area_efficiency <= PAPER_AREA_BAND.1);
            assert!(r.energy_efficiency > 1.0 && r.speedup > 1.0);
        }
        assert!(paper_reference("bogus").is_none());
    }

    #[test]
    fn trace_modes_build_the_right_sim_config() {
        let s = TraceMode::Sampled(64).sim_config();
        assert_eq!(s.sample_positions, Some(64));
        assert!(!s.is_exact());
        let e = TraceMode::Exact.sim_config();
        assert!(e.is_exact());
        assert_eq!(TraceMode::Exact.name(), "exact");
        assert_eq!(TraceMode::Sampled(8).name(), "sampled");
        // both modes share the trace seed: the only difference is the
        // sampling
        assert_eq!(s.seed, e.seed);
    }

    #[test]
    fn artifact_bundle_json_roundtrips() {
        let a = bundle("cifar10", 1e6, 1e5);
        let back = DatasetArtifact::from_json(&a.to_json()).expect("roundtrip");
        assert_eq!(a, back);
        assert_eq!(a.metric("fig7", "naive_crossbars"), Some(400.0));
        assert_eq!(a.metric("table2", "speedup"), Some(2.0));
        assert_eq!(a.metric("nope", "x"), None);
        assert!(DatasetArtifact::from_json(&Json::Null).is_none());
        // a bundle missing a section is rejected, not half-parsed
        let mut j = a.to_json();
        if let Json::Obj(o) = &mut j {
            o.remove("fig8");
        }
        assert!(DatasetArtifact::from_json(&j).is_none());
    }

    #[test]
    fn delta_report_flags_only_out_of_band_metrics() {
        let sampled = run(
            TraceMode::Sampled(64),
            vec![bundle("cifar10", 1.03e6, 1.02e5)],
        );
        let exact = run(TraceMode::Exact, vec![bundle("cifar10", 1e6, 1e5)]);
        let tol = DeltaTolerances::default();
        let r = delta_report(&sampled, &exact, &tol).expect("report");
        assert_eq!(r.entries.len(), delta_metrics().len());
        assert_eq!(r.sampled_positions, Some(64));
        // structural metrics are identical -> zero delta
        for e in &r.entries {
            if e.metric == "crossbars" || e.metric == "sparsity" {
                assert_eq!(e.rel_delta, 0.0, "{}/{}", e.figure, e.metric);
            }
        }
        // 2-3% energy/cycle deltas sit inside the 10% bands
        assert!(r.all_within(), "{}", r.lines());
        assert!(r.max_rel_delta() > 0.0);
        let j = r.to_json();
        assert_eq!(j.get("all_within").as_bool(), Some(true));
        assert_eq!(j.get("n_violations").as_usize(), Some(0));
        assert_eq!(
            j.get("entries").as_arr().map(|a| a.len()),
            Some(delta_metrics().len())
        );

        // push the sampled energy out of band: exactly the energy
        // metrics trip, everything else stays green
        let bad =
            run(TraceMode::Sampled(64), vec![bundle("cifar10", 1.5e6, 1.02e5)]);
        let r = delta_report(&bad, &exact, &tol).expect("report");
        assert!(!r.all_within());
        let v = r.violations();
        assert!(!v.is_empty());
        assert!(v.iter().all(|e| e.metric == "energy_pj"), "{}", r.lines());
        assert!(r.lines().contains("OUT OF BAND"), "{}", r.lines());
    }

    #[test]
    fn delta_report_rejects_mismatched_runs() {
        let sampled =
            run(TraceMode::Sampled(64), vec![bundle("cifar10", 1e6, 1e5)]);
        let exact = run(TraceMode::Exact, vec![bundle("cifar10", 1e6, 1e5)]);
        let tol = DeltaTolerances::default();
        // swapped arguments must not produce an inverted report
        let e = delta_report(&exact, &sampled, &tol).unwrap_err();
        assert!(e.contains("swapped"), "{e}");
        // two sampled runs (or two exact runs) are not a comparison
        assert!(delta_report(&sampled, &sampled, &tol).is_err());
        // different weight seeds simulate different networks
        let other_seed = PaperArtifacts {
            mode: TraceMode::Exact,
            seed: 7,
            datasets: vec![bundle("cifar10", 1e6, 1e5)],
            cache_hits: 0,
        };
        let e = delta_report(&sampled, &other_seed, &tol).unwrap_err();
        assert!(e.contains("seed mismatch"), "{e}");
        let exact_empty = run(TraceMode::Exact, vec![]);
        assert!(delta_report(&sampled, &exact_empty, &tol).is_err());
        let exact_other =
            run(TraceMode::Exact, vec![bundle("cifar100", 1e6, 1e5)]);
        assert!(delta_report(&sampled, &exact_other, &tol).is_err());
        // a bundle missing a compared metric is an error, not a skip
        let mut broken = bundle("cifar10", 1e6, 1e5);
        broken.table2 = obj(vec![("naive_cycles", Json::Num(1.0))]);
        let exact_broken = run(TraceMode::Exact, vec![broken]);
        let e = delta_report(&sampled, &exact_broken, &tol).unwrap_err();
        assert!(e.contains("missing"), "{e}");
    }

    #[test]
    fn figure_jsons_carry_mode_and_rows() {
        let p = run(
            TraceMode::Exact,
            vec![bundle("cifar10", 1e6, 1e5), bundle("cifar100", 2e6, 2e5)],
        );
        let f7 = p.fig7_json();
        assert_eq!(f7.get("mode").as_str(), Some("exact"));
        assert_eq!(f7.get("sample_positions"), &Json::Null);
        assert_eq!(f7.get("seed").as_usize(), Some(42));
        assert_eq!(f7.get("rows").as_arr().map(|r| r.len()), Some(2));
        let s = run(TraceMode::Sampled(64), vec![bundle("cifar10", 1e6, 1e5)]);
        assert_eq!(s.table2_json().get("sample_positions").as_usize(), Some(64));
        assert!(p.dataset("cifar100").is_some());
        assert!(p.dataset("imagenet").is_none());
    }

    #[test]
    fn artifact_cache_roundtrips_and_separates_identities() {
        use crate::pruning::synthetic::{CIFAR10, CIFAR100};
        let dir = std::env::temp_dir().join(format!(
            "rram-artifact-cache-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let c = ArtifactCache::new(dir.clone());
        let sampled = ArtifactConfig {
            seed: 42,
            mode: TraceMode::Sampled(64),
            threads: 2,
        };
        let a = bundle("cifar10", 1.25e6, 1.0e5); // exactly representable
        assert!(c.load(&CIFAR10, &sampled).is_none(), "cold cache");
        c.store(&CIFAR10, &sampled, &a).unwrap();
        let got = c.load(&CIFAR10, &sampled).expect("hit");
        assert_eq!(got, a);

        // a different thread count is the SAME identity (results are
        // thread-invariant)
        let threads4 = ArtifactConfig { threads: 4, ..sampled };
        assert!(c.load(&CIFAR10, &threads4).is_some());

        // trace mode, sample count, seed and dataset all separate
        let exact = ArtifactConfig { mode: TraceMode::Exact, ..sampled };
        assert!(c.load(&CIFAR10, &exact).is_none(), "mode separates");
        let s16 =
            ArtifactConfig { mode: TraceMode::Sampled(16), ..sampled };
        assert!(c.load(&CIFAR10, &s16).is_none(), "sample count separates");
        let seed7 = ArtifactConfig { seed: 7, ..sampled };
        assert!(c.load(&CIFAR10, &seed7).is_none(), "seed separates");
        assert!(c.load(&CIFAR100, &sampled).is_none(), "dataset separates");

        // editing the profile's published statistics invalidates the
        // entry — identity covers contents, not just the name
        let mut tweaked = CIFAR10.clone();
        tweaked.sparsity = 0.5;
        assert!(
            c.load(&tweaked, &sampled).is_none(),
            "profile contents separate"
        );
        let mut repatterned = CIFAR10.clone();
        repatterned.patterns_per_layer[0] = 9;
        assert!(
            c.load(&repatterned, &sampled).is_none(),
            "pattern counts separate"
        );

        // a corrupt legacy entry (no pack record for this identity)
        // reads as a miss and heals on re-store
        let (key16, _) = ArtifactCache::identity(&CIFAR10, &s16);
        std::fs::write(c.path_for(key16), "{truncated").unwrap();
        assert!(c.load(&CIFAR10, &s16).is_none());
        c.store(&CIFAR10, &s16, &a).unwrap();
        assert!(c.load(&CIFAR10, &s16).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The pack store supersedes the per-file JSON layout, but existing
    /// entries must keep hitting: a legacy file is read, verified, and
    /// migrated into the pack.
    #[test]
    fn artifact_cache_reads_and_migrates_legacy_json_entries() {
        use crate::pruning::synthetic::CIFAR10;
        let dir = std::env::temp_dir().join(format!(
            "rram-artifact-legacy-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = ArtifactConfig {
            seed: 42,
            mode: TraceMode::Sampled(64),
            threads: 2,
        };
        let a = bundle("cifar10", 1.25e6, 1.0e5);
        // hand-write the historical pretty-printed per-file entry
        let (key, id) = ArtifactCache::identity(&CIFAR10, &cfg);
        let entry = obj(vec![
            ("format", ARTIFACT_CACHE_FORMAT.into()),
            ("identity", id.into()),
            ("artifact", a.to_json()),
        ]);
        std::fs::write(
            dir.join(format!("{key:016x}.json")),
            entry.to_string_pretty(),
        )
        .unwrap();

        let c = ArtifactCache::new(dir.clone());
        assert_eq!(c.load(&CIFAR10, &cfg), Some(a.clone()), "legacy hit");
        // migrated: remove the JSON file, the pack still serves it
        std::fs::remove_file(c.path_for(key)).unwrap();
        assert_eq!(c.load(&CIFAR10, &cfg), Some(a), "served from pack");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
