//! Pattern analysis & pruning (paper §II-B, §III-A).
//!
//! A *pattern* is the boolean nonzero-mask of a 3×3 kernel, encoded as a
//! 9-bit id (bit `i` = kernel position `(i / 3, i % 3)`), identical to
//! `python/compile/pruning.py`. This module provides extraction and
//! statistics over real weight tensors, a rust-side magnitude-prune +
//! pattern-projection pipeline (used by tests and standalone tools), and
//! the Table-II-calibrated synthetic VGG16 generator ([`synthetic`]).

pub mod synthetic;

use std::collections::BTreeMap;

use crate::nn::{NetworkSpec, Tensor};

/// A 3×3 kernel pattern: 9-bit nonzero mask.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Pattern(pub u16);

impl Pattern {
    pub const ALL_ZERO: Pattern = Pattern(0);
    pub const FULL: Pattern = Pattern(0x1FF);

    /// Pattern of a 3×3 kernel slice (9 contiguous f32s).
    pub fn from_kernel(k: &[f32]) -> Pattern {
        debug_assert_eq!(k.len(), 9);
        let mut id = 0u16;
        for (i, v) in k.iter().enumerate() {
            if *v != 0.0 {
                id |= 1 << i;
            }
        }
        Pattern(id)
    }

    /// Number of nonzero positions ("pattern size" in the paper).
    pub fn size(&self) -> usize {
        self.0.count_ones() as usize
    }

    pub fn is_zero(&self) -> bool {
        self.0 == 0
    }

    /// Kernel positions (0..9) present in this pattern, ascending.
    pub fn positions(&self) -> Vec<usize> {
        (0..9).filter(|i| self.0 >> i & 1 == 1).collect()
    }

    pub fn contains(&self, pos: usize) -> bool {
        pos < 9 && self.0 >> pos & 1 == 1
    }

    /// Is `other` a subset of `self`?
    pub fn superset_of(&self, other: Pattern) -> bool {
        other.0 & !self.0 == 0
    }

    pub fn hamming(&self, other: Pattern) -> usize {
        (self.0 ^ other.0).count_ones() as usize
    }
}

/// Per-kernel view of a conv layer: `kernel(cout, cin)` slices.
pub fn kernel_slice<'a>(w: &'a Tensor, cout: usize, cin: usize) -> &'a [f32] {
    let base = w.idx4(cout, cin, 0, 0);
    &w.data[base..base + 9]
}

/// Pattern PDF of one layer's `[cout, cin, 3, 3]` weights.
pub fn layer_pattern_counts(w: &Tensor) -> BTreeMap<Pattern, usize> {
    let (cout, cin) = (w.shape[0], w.shape[1]);
    let mut counts = BTreeMap::new();
    for o in 0..cout {
        for i in 0..cin {
            let p = Pattern::from_kernel(kernel_slice(w, o, i));
            *counts.entry(p).or_insert(0) += 1;
        }
    }
    counts
}

/// Top-n candidate patterns by probability; the all-zero pattern, when
/// present, is always kept (its kernels are deleted from the crossbar).
pub fn select_candidates(
    counts: &BTreeMap<Pattern, usize>,
    n: usize,
) -> Vec<Pattern> {
    let mut ranked: Vec<(Pattern, usize)> =
        counts.iter().map(|(p, c)| (*p, *c)).collect();
    // by count desc, pattern id asc for determinism
    ranked.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    let mut cands: Vec<Pattern> =
        ranked.iter().take(n).map(|(p, _)| *p).collect();
    if counts.contains_key(&Pattern::ALL_ZERO)
        && !cands.contains(&Pattern::ALL_ZERO)
    {
        cands.pop();
        cands.push(Pattern::ALL_ZERO);
    }
    cands
}

/// Magnitude-prune a layer to at least `sparsity` zeros (global threshold
/// over the layer, mirroring `pruning.magnitude_prune`).
pub fn magnitude_prune(w: &Tensor, sparsity: f64) -> Tensor {
    let mut mags: Vec<f32> = w.data.iter().map(|v| v.abs()).collect();
    let k = (sparsity * mags.len() as f64).ceil() as usize;
    let mut out = w.clone();
    if k == 0 {
        return out;
    }
    let k = k.min(mags.len());
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let thresh = mags[k - 1];
    for v in out.data.iter_mut() {
        if v.abs() <= thresh {
            *v = 0.0;
        }
    }
    out
}

/// Project one kernel onto the candidate retaining the most L2 energy
/// (ties → smaller pattern). Returns (projected kernel, assigned pattern).
pub fn project_kernel(k: &[f32], candidates: &[Pattern]) -> ([f32; 9], Pattern) {
    let mut best = Pattern::ALL_ZERO;
    let mut best_key = (f64::NEG_INFINITY, usize::MAX);
    for p in candidates {
        let kept: f64 = p
            .positions()
            .iter()
            .map(|&i| (k[i] as f64) * (k[i] as f64))
            .sum();
        let key = (kept, usize::MAX - p.size());
        if key.0 > best_key.0
            || (key.0 == best_key.0 && key.1 > best_key.1)
        {
            best_key = key;
            best = *p;
        }
    }
    let mut out = [0.0f32; 9];
    for i in best.positions() {
        out[i] = k[i];
    }
    (out, best)
}

/// Project every kernel of a layer; returns the projected tensor and the
/// per-kernel pattern assignment `[cout * cin]` (cin-minor).
pub fn project_layer(w: &Tensor, candidates: &[Pattern]) -> (Tensor, Vec<Pattern>) {
    let (cout, cin) = (w.shape[0], w.shape[1]);
    let mut out = w.clone();
    let mut assigned = Vec::with_capacity(cout * cin);
    for o in 0..cout {
        for i in 0..cin {
            let (proj, pat) = project_kernel(kernel_slice(w, o, i), candidates);
            let base = out.idx4(o, i, 0, 0);
            out.data[base..base + 9].copy_from_slice(&proj);
            assigned.push(pat);
        }
    }
    (out, assigned)
}

/// A network's weights aligned with a [`NetworkSpec`].
#[derive(Debug, Clone)]
pub struct NetworkWeights {
    pub spec: NetworkSpec,
    /// One `[cout, cin, 3, 3]` tensor per conv layer.
    pub layers: Vec<Tensor>,
}

impl NetworkWeights {
    pub fn new(spec: NetworkSpec, layers: Vec<Tensor>) -> NetworkWeights {
        assert_eq!(spec.layers.len(), layers.len());
        for (l, w) in spec.layers.iter().zip(layers.iter()) {
            assert_eq!(w.shape, vec![l.cout, l.cin, 3, 3], "layer {}", l.name);
        }
        NetworkWeights { spec, layers }
    }

    /// Table-II-style statistics.
    pub fn stats(&self) -> NetworkStats {
        let mut total_w = 0usize;
        let mut zero_w = 0usize;
        let mut total_k = 0usize;
        let mut zero_k = 0usize;
        let mut patterns_per_layer = Vec::new();
        for w in &self.layers {
            total_w += w.numel();
            zero_w += w.count_zeros();
            let counts = layer_pattern_counts(w);
            patterns_per_layer.push(counts.len());
            for (p, c) in &counts {
                total_k += c;
                if p.is_zero() {
                    zero_k += c;
                }
            }
        }
        NetworkStats {
            sparsity: zero_w as f64 / total_w.max(1) as f64,
            patterns_per_layer: patterns_per_layer.clone(),
            total_patterns: patterns_per_layer.iter().sum(),
            all_zero_kernel_ratio: zero_k as f64 / total_k.max(1) as f64,
        }
    }
}

/// Summary statistics matching the paper's Table II columns.
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkStats {
    pub sparsity: f64,
    pub patterns_per_layer: Vec<usize>,
    pub total_patterns: usize,
    pub all_zero_kernel_ratio: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kernel(vals: [f32; 9]) -> Vec<f32> {
        vals.to_vec()
    }

    #[test]
    fn pattern_from_kernel_roundtrip() {
        let k = kernel([1.0, 0.0, 0.0, 0.0, 2.0, 0.0, 0.0, 0.0, -3.0]);
        let p = Pattern::from_kernel(&k);
        assert_eq!(p.0, 0b100010001);
        assert_eq!(p.size(), 3);
        assert_eq!(p.positions(), vec![0, 4, 8]);
        assert!(p.contains(4));
        assert!(!p.contains(1));
    }

    #[test]
    fn pattern_relations() {
        let a = Pattern(0b111);
        let b = Pattern(0b101);
        assert!(a.superset_of(b));
        assert!(!b.superset_of(a));
        assert_eq!(a.hamming(b), 1);
        assert!(Pattern::FULL.superset_of(a));
        assert_eq!(Pattern::ALL_ZERO.size(), 0);
        assert!(Pattern::ALL_ZERO.is_zero());
    }

    #[test]
    fn layer_counts_and_candidates() {
        // 4 kernels: two with pattern A, one B, one all-zero
        let mut w = Tensor::zeros(&[4, 1, 3, 3]);
        w.set4(0, 0, 0, 0, 1.0); // A = {0}
        w.set4(1, 0, 0, 0, 2.0); // A
        w.set4(2, 0, 1, 1, 3.0); // B = {4}
        // kernel 3 all-zero
        let counts = layer_pattern_counts(&w);
        assert_eq!(counts[&Pattern(1)], 2);
        assert_eq!(counts[&Pattern(1 << 4)], 1);
        assert_eq!(counts[&Pattern::ALL_ZERO], 1);

        let cands = select_candidates(&counts, 2);
        assert_eq!(cands.len(), 2);
        assert!(cands.contains(&Pattern(1)));
        assert!(cands.contains(&Pattern::ALL_ZERO)); // forced keep
    }

    #[test]
    fn magnitude_prune_thresholds() {
        let w = Tensor::from_vec(
            &[1, 1, 3, 3],
            vec![1.0, -2.0, 3.0, -4.0, 5.0, -6.0, 7.0, -8.0, 9.0],
        );
        let wp = magnitude_prune(&w, 5.0 / 9.0);
        let nz: Vec<f32> = wp.data.iter().copied().filter(|v| *v != 0.0).collect();
        assert_eq!(nz, vec![-6.0, 7.0, -8.0, 9.0]);
        // zero sparsity = identity
        assert_eq!(magnitude_prune(&w, 0.0).data, w.data);
    }

    #[test]
    fn projection_retains_max_energy() {
        let k = [10.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0];
        let cands = [Pattern(1), Pattern(1 << 8)];
        let (out, pat) = project_kernel(&k, &cands);
        assert_eq!(pat, Pattern(1));
        assert_eq!(out[0], 10.0);
        assert_eq!(out[8], 0.0);
    }

    #[test]
    fn project_layer_assignments_within_candidates() {
        let mut w = Tensor::zeros(&[3, 2, 3, 3]);
        for (i, v) in w.data.iter_mut().enumerate() {
            *v = ((i * 7919) % 13) as f32 - 6.0;
        }
        let wp = magnitude_prune(&w, 0.6);
        let counts = layer_pattern_counts(&wp);
        let cands = select_candidates(&counts, 3);
        let (proj, assigned) = project_layer(&wp, &cands);
        assert_eq!(assigned.len(), 6);
        for (ki, pat) in assigned.iter().enumerate() {
            assert!(cands.contains(pat), "kernel {ki}");
            let (o, i) = (ki / 2, ki % 2);
            let obs = Pattern::from_kernel(kernel_slice(&proj, o, i));
            assert!(pat.superset_of(obs));
        }
    }

    #[test]
    fn stats_on_known_network() {
        let spec = NetworkSpec {
            name: "tiny".into(),
            layers: vec![crate::nn::ConvLayer {
                name: "conv0".into(),
                cin: 1,
                cout: 4,
                fmap: 8,
            }],
        };
        let mut w = Tensor::zeros(&[4, 1, 3, 3]);
        w.set4(0, 0, 0, 0, 1.0);
        w.set4(1, 0, 0, 0, 1.0);
        w.set4(2, 0, 1, 1, 1.0);
        let nw = NetworkWeights::new(spec, vec![w]);
        let s = nw.stats();
        assert_eq!(s.patterns_per_layer, vec![3]);
        assert_eq!(s.total_patterns, 3);
        assert!((s.all_zero_kernel_ratio - 0.25).abs() < 1e-12);
        assert!((s.sparsity - 33.0 / 36.0).abs() < 1e-12);
    }
}
