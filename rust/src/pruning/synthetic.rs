//! Table-II-calibrated synthetic pattern-pruned VGG16 generator.
//!
//! We do not have the authors' trained + ADMM-pruned VGG16 checkpoints
//! (nor ImageNet), so — per the substitution rule in DESIGN.md §3 — this
//! module synthesizes weight tensors whose *sparsity structure* matches
//! the paper's published Table II statistics exactly where they are
//! given (per-layer pattern counts, overall sparsity, all-zero-kernel
//! ratio). The mapping/energy/cycle results depend only on this
//! structure, not on the float values, which are drawn from a normal
//! distribution.

use crate::nn::{NetworkSpec, Tensor};
use crate::pruning::{NetworkWeights, Pattern};
use crate::util::rng::Rng;

/// Published Table II statistics for one dataset row.
#[derive(Debug, Clone)]
pub struct DatasetProfile {
    pub name: &'static str,
    /// Overall conv sparsity after pattern pruning (Table II col 2).
    pub sparsity: f64,
    /// Patterns per conv layer, including the all-zero pattern
    /// (Table II col 3; 13 entries).
    pub patterns_per_layer: [usize; 13],
    /// All-zero kernel ratio (paper §V-D).
    pub all_zero_ratio: f64,
    /// Baseline (irregular-pruned) sparsity, for the "theoretical best"
    /// line of Fig. 7 — equals the pattern-pruned sparsity in Table II.
    pub top1: &'static str,
    pub top5: &'static str,
    /// ImageNet-sized feature maps?
    pub imagenet_fmaps: bool,
}

pub const CIFAR10: DatasetProfile = DatasetProfile {
    name: "cifar10",
    sparsity: 0.8603,
    patterns_per_layer: [2, 2, 2, 6, 8, 8, 8, 6, 5, 4, 6, 6, 8],
    all_zero_ratio: 0.409,
    top1: "92.63%(-0.09%)",
    top5: "/",
    imagenet_fmaps: false,
};

pub const CIFAR100: DatasetProfile = DatasetProfile {
    name: "cifar100",
    sparsity: 0.8523,
    patterns_per_layer: [2, 2, 2, 2, 2, 8, 8, 8, 5, 6, 7, 6, 8],
    all_zero_ratio: 0.274,
    top1: "72.73%(+0.01%)",
    top5: "92.23%(+0.79%)",
    imagenet_fmaps: false,
};

pub const IMAGENET: DatasetProfile = DatasetProfile {
    name: "imagenet",
    sparsity: 0.8248,
    patterns_per_layer: [2, 2, 2, 2, 2, 9, 12, 12, 9, 10, 6, 4, 4],
    all_zero_ratio: 0.285,
    top1: "71.15%(-0.75%)",
    top5: "89.98%(-0.51%)",
    imagenet_fmaps: true,
};

pub const ALL_PROFILES: [&DatasetProfile; 3] = [&CIFAR10, &CIFAR100, &IMAGENET];

impl DatasetProfile {
    pub fn by_name(name: &str) -> Option<&'static DatasetProfile> {
        ALL_PROFILES.iter().find(|p| p.name == name).copied()
    }

    pub fn network_spec(&self) -> NetworkSpec {
        if self.imagenet_fmaps {
            NetworkSpec::vgg16_imagenet(&format!("vgg16-{}", self.name))
        } else {
            NetworkSpec::vgg16_cifar(&format!("vgg16-{}", self.name))
        }
    }

    /// Generate the full synthetic pattern-pruned VGG16 for this profile.
    pub fn generate(&self, seed: u64) -> NetworkWeights {
        let spec = self.network_spec();
        let mut rng = Rng::seed_from(seed ^ crate::util::fnv1a(self.name));
        let mut layers = Vec::with_capacity(13);
        for (li, layer) in spec.layers.iter().enumerate() {
            let mut lrng = rng.fork(li as u64);
            layers.push(generate_layer(
                layer.cout,
                layer.cin,
                self.patterns_per_layer[li],
                self.sparsity,
                self.all_zero_ratio,
                &mut lrng,
            ));
        }
        NetworkWeights::new(spec, layers)
    }
}

/// Sample `n` distinct nonzero patterns with the given sizes.
///
/// If the masks of a size are exhausted (e.g. two patterns of size 9 —
/// only one mask exists), the size is relaxed outward (s-1, s+1, ...)
/// so the result always has `sizes.len()` distinct nonzero patterns.
fn sample_patterns(sizes: &[usize], rng: &mut Rng) -> Vec<Pattern> {
    let mut out: Vec<Pattern> = Vec::with_capacity(sizes.len());
    'next: for &s in sizes {
        // random attempts at the requested size first
        for _ in 0..64 {
            let pos = rng.sample_indices(9, s);
            let mut id = 0u16;
            for p in pos {
                id |= 1 << p;
            }
            let pat = Pattern(id);
            if !out.contains(&pat) {
                out.push(pat);
                continue 'next;
            }
        }
        // deterministic fallback: scan sizes s, s-1, s+1, s-2, ...
        for delta in 0..9i32 {
            for cand_s in [s as i32 - delta, s as i32 + delta] {
                if !(1..=9).contains(&cand_s) {
                    continue;
                }
                for mask in 1u16..512 {
                    let pat = Pattern(mask);
                    if pat.size() == cand_s as usize && !out.contains(&pat) {
                        out.push(pat);
                        continue 'next;
                    }
                }
            }
        }
        unreachable!("fewer than 511 patterns requested");
    }
    out
}

/// Generate one layer's `[cout, cin, 3, 3]` tensor with exactly
/// `n_patterns` distinct patterns (including all-zero when
/// `zero_ratio > 0`), hitting the target sparsity as closely as the
/// pattern-count constraint allows.
pub fn generate_layer(
    cout: usize,
    cin: usize,
    n_patterns: usize,
    sparsity: f64,
    zero_ratio: f64,
    rng: &mut Rng,
) -> Tensor {
    assert!(n_patterns >= 1);
    let kernels = cout * cin;
    // A zero pattern needs its own slot among n_patterns; with a single
    // pattern the layer is all-nonzero (the degenerate all-zero layer is
    // not a useful synthetic target).
    let n_zero = if n_patterns == 1 {
        0
    } else {
        ((zero_ratio * kernels as f64).round() as usize)
            .min(kernels.saturating_sub(n_patterns - 1))
    };
    let n_nonzero_kernels = kernels - n_zero;
    let n_nonzero_patterns = if n_zero > 0 { n_patterns - 1 } else { n_patterns };
    assert!(n_nonzero_patterns >= 1, "need at least one nonzero pattern");
    assert!(n_nonzero_kernels >= n_nonzero_patterns);

    // Mean nonzero-pattern size that yields the target overall sparsity:
    // (1 - zr) * mean_size = 9 * (1 - sparsity).
    let target_nnz = ((1.0 - sparsity) * (kernels * 9) as f64).round() as usize;
    let mean_size =
        (target_nnz as f64 / n_nonzero_kernels.max(1) as f64).clamp(1.0, 9.0);

    // Spread pattern sizes around the mean (distinct masks sampled below).
    let lo = (mean_size.floor() as usize).max(1);
    let hi = (mean_size.ceil() as usize + 2).min(9);
    let mut sizes: Vec<usize> = if n_nonzero_patterns == 1 {
        // single pattern: its size fully determines the sparsity
        vec![(mean_size.round() as usize).clamp(1, 9)]
    } else {
        (0..n_nonzero_patterns)
            .map(|i| {
                if i == 0 {
                    hi // the "biggest pattern" the placement leads with
                } else {
                    rng.range(lo, hi + 1)
                }
            })
            .collect()
    };
    // Keep at least one small pattern for diversity when we can afford it.
    if n_nonzero_patterns >= 3 {
        let last = sizes.len() - 1;
        sizes[last] = lo;
    }
    let patterns = sample_patterns(&sizes, rng);

    // Initial assignment: one kernel per pattern (so every pattern shows
    // up), the rest Zipf-weighted toward the leading patterns.
    let mut assignment: Vec<usize> = Vec::with_capacity(n_nonzero_kernels);
    for i in 0..n_nonzero_patterns {
        assignment.push(i);
    }
    let zipf: Vec<f64> = (0..n_nonzero_patterns)
        .map(|i| 1.0 / (i as f64 + 1.0))
        .collect();
    for _ in n_nonzero_patterns..n_nonzero_kernels {
        assignment.push(rng.weighted(&zipf));
    }

    // Greedy repair toward the exact nonzero-weight target: move kernels
    // between patterns of different sizes. Per-pattern population counts
    // are maintained incrementally (an O(K) scan per move would make
    // VGG-scale layers quadratic).
    let mut pop = vec![0usize; n_nonzero_patterns];
    for &p in &assignment {
        pop[p] += 1;
    }
    let mut cur: i64 = assignment
        .iter()
        .map(|&p| patterns[p].size() as i64)
        .sum();
    let target = target_nnz as i64;
    let min_size = *sizes.iter().min().unwrap() as i64;
    let max_size = *sizes.iter().max().unwrap() as i64;
    for _ in 0..kernels * 4 {
        let diff = cur - target;
        if diff.abs() < min_size.max(1) || min_size == max_size {
            break;
        }
        let ki = rng.below(n_nonzero_kernels);
        let from = assignment[ki];
        let to = rng.below(n_nonzero_patterns);
        let delta = patterns[to].size() as i64 - patterns[from].size() as i64;
        // Accept moves that shrink |cur - target| and keep every pattern
        // populated.
        if (cur + delta - target).abs() < diff.abs() && pop[from] > 1 {
            assignment[ki] = to;
            pop[from] -= 1;
            pop[to] += 1;
            cur += delta;
        }
    }

    // Lay out kernels: choose which (cout, cin) slots are all-zero.
    let mut slot_order: Vec<usize> = (0..kernels).collect();
    rng.shuffle(&mut slot_order);
    let mut w = Tensor::zeros(&[cout, cin, 3, 3]);
    for (idx, &slot) in slot_order.iter().enumerate() {
        if idx < n_zero {
            continue; // all-zero kernel
        }
        let pat = patterns[assignment[idx - n_zero]];
        let (o, i) = (slot / cin, slot % cin);
        let base = w.idx4(o, i, 0, 0);
        for pos in pat.positions() {
            // avoid exact zeros in nonzero positions
            let mut v = 0.0f32;
            while v == 0.0 {
                v = (rng.normal() * 0.05) as f32;
            }
            w.data[base + pos] = v;
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::layer_pattern_counts;

    #[test]
    fn layer_hits_pattern_count_and_zero_ratio() {
        let mut rng = Rng::seed_from(1);
        let w = generate_layer(64, 32, 8, 0.86, 0.40, &mut rng);
        let counts = layer_pattern_counts(&w);
        assert_eq!(counts.len(), 8);
        let zeros = counts.get(&Pattern::ALL_ZERO).copied().unwrap_or(0);
        let ratio = zeros as f64 / (64.0 * 32.0);
        assert!((ratio - 0.40).abs() < 0.01, "zero ratio {ratio}");
    }

    #[test]
    fn layer_sparsity_close_to_target() {
        let mut rng = Rng::seed_from(2);
        let w = generate_layer(128, 64, 8, 0.85, 0.30, &mut rng);
        let sp = w.count_zeros() as f64 / w.numel() as f64;
        assert!((sp - 0.85).abs() < 0.02, "sparsity {sp}");
    }

    #[test]
    fn profiles_match_table2() {
        assert_eq!(CIFAR10.patterns_per_layer.iter().sum::<usize>(), 71);
        assert_eq!(CIFAR100.patterns_per_layer.iter().sum::<usize>(), 66);
        assert_eq!(IMAGENET.patterns_per_layer.iter().sum::<usize>(), 76);
        assert!(DatasetProfile::by_name("cifar10").is_some());
        assert!(DatasetProfile::by_name("bogus").is_none());
    }

    #[test]
    fn generated_network_stats_match_profile() {
        // smoke on the smaller CIFAR profile; full check in integration
        let nw = CIFAR10.generate(42);
        let stats = nw.stats();
        assert_eq!(stats.patterns_per_layer.len(), 13);
        for (got, want) in stats
            .patterns_per_layer
            .iter()
            .zip(CIFAR10.patterns_per_layer.iter())
        {
            assert_eq!(got, want);
        }
        assert!(
            (stats.sparsity - CIFAR10.sparsity).abs() < 0.02,
            "sparsity {} vs {}",
            stats.sparsity,
            CIFAR10.sparsity
        );
        assert!(
            (stats.all_zero_kernel_ratio - CIFAR10.all_zero_ratio).abs() < 0.02,
            "zr {} vs {}",
            stats.all_zero_kernel_ratio,
            CIFAR10.all_zero_ratio
        );
    }

    #[test]
    fn generation_is_deterministic() {
        let a = CIFAR10.generate(7);
        let b = CIFAR10.generate(7);
        for (x, y) in a.layers.iter().zip(b.layers.iter()) {
            assert_eq!(x.data, y.data);
        }
    }
}
