//! # rram-pattern-accel
//!
//! Reproduction of *"High Area/Energy Efficiency RRAM CNN Accelerator with
//! Kernel-Reordering Weight Mapping Scheme Based on Pattern Pruning"*
//! (CS.AR 2020).
//!
//! The crate hosts the paper's contribution — the pattern-pruned,
//! kernel-reordered weight mapping scheme ([`mapping`]) and the
//! accelerator architecture that executes it ([`arch`], [`sim`]) — plus
//! every substrate it needs: the RRAM crossbar / ADC / DAC models
//! ([`xbar`]), pattern analysis ([`pruning`]), network + tensor handling
//! ([`nn`]), the PJRT runtime that executes the AOT-compiled JAX
//! functional model ([`runtime`]), a serving coordinator
//! ([`coordinator`]), a design-space exploration engine that sweeps
//! mapping/OU/crossbar configurations and auto-tunes the serving stack
//! from the Pareto frontier ([`dse`]), a binary content-addressed
//! artifact store backing the sweep and report caches ([`store`]),
//! an end-to-end tracing and histogram-metrics layer spanning the
//! serving pipeline ([`obs`]), report generation for every
//! paper table and figure ([`report`]), and small from-scratch
//! utilities ([`util`]) standing in for crates unavailable in this
//! offline image.
//!
//! The crate also checks its own determinism contract statically: the
//! [`analysis`] module implements the `rram-accel lint` pass (rule set,
//! suppression pragmas, deterministic reports) and
//! [`util::lockcheck`] the runtime lock-order probe behind the
//! `lockcheck` feature.
//!
//! See `DESIGN.md` for the full system inventory and experiment index.

pub mod analysis;
pub mod arch;
pub mod config;
pub mod coordinator;
pub mod dse;
pub mod mapping;
pub mod nn;
pub mod obs;
pub mod pruning;
pub mod report;
pub mod runtime;
pub mod serve_http;
pub mod sim;
pub mod store;
pub mod util;
pub mod xbar;
