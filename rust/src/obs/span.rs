//! Spans, per-buffer rings, and the process-wide [`Registry`].
//!
//! Everything here is allocation-free after setup: a [`SpanRecord`] is
//! `Copy` (fixed-size argument array, `&'static str` names), a ring's
//! slot vector is allocated once at registration, and recording a span
//! is one clock read plus one slot write under the ring's mutex.
//! Timestamps come exclusively from the injected
//! [`crate::util::clock::Clock`] — this module never reads wall time
//! (it sits inside the `no-wall-clock-in-pure-paths` lint scope).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::util::clock::Clock;
use crate::util::lockcheck;

/// Fixed argument capacity of one span record; extra arguments passed
/// to [`Registry::end`]/[`Registry::record`] are dropped (never
/// reallocated).
pub const MAX_SPAN_ARGS: usize = 4;

/// Default per-buffer ring capacity (spans kept per thread/role).
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// Trace context carried through a request's life: the request-scoped
/// trace ID assigned at the HTTP/coordinator boundary, and the span the
/// next pipeline stage should nest under. `trace_id == 0` means
/// "untraced" — every recording call is a cheap no-op for it.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TraceCtx {
    pub trace_id: u64,
    /// Parent span for the next stage's spans (0 = root).
    pub parent: u64,
}

/// One finished span, as stored in a ring slot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpanRecord {
    /// Request-scoped trace this span belongs to (0 = none recorded).
    pub trace_id: u64,
    /// Unique (per registry) span ID.
    pub span_id: u64,
    /// Enclosing span (0 = root of its trace).
    pub parent_id: u64,
    /// Static span name, e.g. `http.infer`, `pool.queue`.
    pub name: &'static str,
    /// Start, microseconds on the registry clock.
    pub start_us: u64,
    /// Duration in microseconds (0 for instant events).
    pub dur_us: u64,
    /// Buffer ID the span was recorded into (Chrome `tid`).
    pub tid: u64,
    arg_buf: [(&'static str, u64); MAX_SPAN_ARGS],
    n_args: u8,
}

impl SpanRecord {
    const EMPTY: SpanRecord = SpanRecord {
        trace_id: 0,
        span_id: 0,
        parent_id: 0,
        name: "",
        start_us: 0,
        dur_us: 0,
        tid: 0,
        arg_buf: [("", 0); MAX_SPAN_ARGS],
        n_args: 0,
    };

    /// The span's recorded `(key, value)` arguments (logical counters:
    /// bytes scanned, batch fill, cache hits, …).
    pub fn args(&self) -> &[(&'static str, u64)] {
        &self.arg_buf[..self.n_args as usize]
    }

    fn with_args(mut self, args: &[(&'static str, u64)]) -> SpanRecord {
        let n = args.len().min(MAX_SPAN_ARGS);
        self.arg_buf[..n].copy_from_slice(&args[..n]);
        self.n_args = n as u8;
        self
    }
}

/// A span begun but not yet recorded. `span_id == 0` marks an inert
/// span (tracing disabled or untraced request): [`Registry::end`]
/// drops it without touching any ring.
#[derive(Debug, Clone, Copy)]
pub struct ActiveSpan {
    pub trace_id: u64,
    pub span_id: u64,
    pub parent_id: u64,
    pub name: &'static str,
    pub start_us: u64,
}

impl ActiveSpan {
    /// An inert span: ending it records nothing.
    pub const INERT: ActiveSpan = ActiveSpan {
        trace_id: 0,
        span_id: 0,
        parent_id: 0,
        name: "",
        start_us: 0,
    };

    pub fn is_recording(&self) -> bool {
        self.span_id != 0
    }
}

/// Fixed-capacity overwrite-oldest ring (single allocation at
/// construction).
struct Ring {
    slots: Vec<SpanRecord>,
    /// Next slot to (over)write.
    next: usize,
    /// Live records (saturates at capacity).
    len: usize,
}

impl Ring {
    fn push(&mut self, rec: SpanRecord) {
        let cap = self.slots.len();
        self.slots[self.next] = rec;
        self.next = (self.next + 1) % cap;
        self.len = (self.len + 1).min(cap);
    }

    /// Live records, oldest first.
    fn snapshot(&self) -> Vec<SpanRecord> {
        let cap = self.slots.len();
        let mut out = Vec::with_capacity(self.len);
        if self.len < cap {
            out.extend_from_slice(&self.slots[..self.len]);
        } else {
            out.extend_from_slice(&self.slots[self.next..]);
            out.extend_from_slice(&self.slots[..self.next]);
        }
        out
    }
}

/// One registered span ring: typically one per long-lived pipeline
/// thread (`dispatch`, `worker-0`, …); role-shared for ephemeral
/// threads (every HTTP connection handler records into `http`), which
/// keeps the buffer set bounded however many connections come and go.
pub struct SpanBuf {
    name: String,
    tid: u64,
    ring: lockcheck::Mutex<Ring>,
}

impl SpanBuf {
    fn new(name: &str, tid: u64, capacity: usize) -> SpanBuf {
        SpanBuf {
            name: name.to_string(),
            tid,
            ring: lockcheck::Mutex::named(
                "obs.ring",
                Ring {
                    slots: vec![SpanRecord::EMPTY; capacity.max(1)],
                    next: 0,
                    len: 0,
                },
            ),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Buffer ID, used as the Chrome trace `tid`.
    pub fn tid(&self) -> u64 {
        self.tid
    }

    pub fn capacity(&self) -> usize {
        self.ring.lock().slots.len()
    }

    pub fn len(&self) -> usize {
        self.ring.lock().len
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Live records, oldest first.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        self.ring.lock().snapshot()
    }
}

/// Process-wide tracing registry: assigns trace/span IDs, owns the
/// registered rings, and stamps every record from its injected clock.
///
/// Disabled registries (or spans of untraced requests, `trace_id == 0`)
/// cost one atomic load per call — no clock read, no lock, no write —
/// which is what bounds the tracing-off overhead on the serving hot
/// path.
pub struct Registry {
    clock: Arc<dyn Clock>,
    enabled: AtomicBool,
    capacity: usize,
    next_trace: AtomicU64,
    next_span: AtomicU64,
    bufs: lockcheck::Mutex<Vec<Arc<SpanBuf>>>,
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Registry")
            .field("enabled", &self.enabled.load(Ordering::Relaxed))
            .field("capacity", &self.capacity)
            .finish()
    }
}

impl Registry {
    /// A new enabled registry; `capacity` is the per-buffer ring size.
    pub fn new(clock: Arc<dyn Clock>, capacity: usize) -> Arc<Registry> {
        Arc::new(Registry {
            clock,
            enabled: AtomicBool::new(true),
            capacity: capacity.max(1),
            next_trace: AtomicU64::new(1),
            next_span: AtomicU64::new(1),
            bufs: lockcheck::Mutex::named("obs.registry", Vec::new()),
        })
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Current time on the registry clock (0 when disabled, so callers
    /// can stamp unconditionally).
    pub fn now_us(&self) -> u64 {
        if self.enabled() {
            self.clock.now_us()
        } else {
            0
        }
    }

    /// Assign a fresh request-scoped trace ID (0 when disabled, which
    /// downstream recording treats as "untraced").
    pub fn new_trace(&self) -> u64 {
        if self.enabled() {
            self.next_trace.fetch_add(1, Ordering::Relaxed)
        } else {
            0
        }
    }

    /// The ring registered under `name`, creating it on first use.
    /// Call once per thread/role at setup — the lookup scans the
    /// (small, bounded) buffer list under a lock.
    pub fn buffer(&self, name: &str) -> Arc<SpanBuf> {
        let mut bufs = self.bufs.lock();
        if let Some(b) = bufs.iter().find(|b| b.name == name) {
            return b.clone();
        }
        let b = Arc::new(SpanBuf::new(name, bufs.len() as u64 + 1, self.capacity));
        bufs.push(b.clone());
        b
    }

    /// All registered rings, in registration order.
    pub fn buffers(&self) -> Vec<Arc<SpanBuf>> {
        self.bufs.lock().clone()
    }

    /// Begin a span. Inert (records nothing on `end`) when the
    /// registry is disabled or the trace ID is 0.
    pub fn begin(
        &self,
        trace_id: u64,
        parent_id: u64,
        name: &'static str,
    ) -> ActiveSpan {
        if !self.enabled() || trace_id == 0 {
            return ActiveSpan::INERT;
        }
        ActiveSpan {
            trace_id,
            span_id: self.next_span.fetch_add(1, Ordering::Relaxed),
            parent_id,
            name,
            start_us: self.clock.now_us(),
        }
    }

    /// Finish `span` into `buf`, stamping the duration from the
    /// registry clock. Returns the span ID (0 if nothing was recorded)
    /// so follow-up spans can nest under it.
    pub fn end(
        &self,
        buf: &SpanBuf,
        span: ActiveSpan,
        args: &[(&'static str, u64)],
    ) -> u64 {
        if !span.is_recording() {
            return 0;
        }
        let now = self.clock.now_us();
        self.record(
            buf,
            span.trace_id,
            span.parent_id,
            span.name,
            span.start_us,
            now.saturating_sub(span.start_us),
            args,
        )
    }

    /// Record a complete span with explicit timing — used when the
    /// start time predates the recording thread (e.g. a queue span
    /// whose start is the submit timestamp carried in the request).
    /// Returns the new span's ID (0 when disabled/untraced).
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &self,
        buf: &SpanBuf,
        trace_id: u64,
        parent_id: u64,
        name: &'static str,
        start_us: u64,
        dur_us: u64,
        args: &[(&'static str, u64)],
    ) -> u64 {
        if !self.enabled() || trace_id == 0 {
            return 0;
        }
        let span_id = self.next_span.fetch_add(1, Ordering::Relaxed);
        let rec = SpanRecord {
            trace_id,
            span_id,
            parent_id,
            name,
            start_us,
            dur_us,
            tid: buf.tid,
            arg_buf: [("", 0); MAX_SPAN_ARGS],
            n_args: 0,
        }
        .with_args(args);
        buf.ring.lock().push(rec);
        span_id
    }

    /// Merged view of every ring, sorted by `(start_us, span_id)` —
    /// a stable causal order even when buffers wrapped independently.
    pub fn snapshot(&self) -> Vec<SpanRecord> {
        let bufs = self.buffers();
        let mut out = Vec::new();
        for b in &bufs {
            out.extend(b.snapshot());
        }
        out.sort_by_key(|r| (r.start_us, r.span_id));
        out
    }

    /// The last `n` spans of the merged, time-sorted view.
    pub fn snapshot_last(&self, n: usize) -> Vec<SpanRecord> {
        let all = self.snapshot();
        let skip = all.len().saturating_sub(n);
        all[skip..].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::clock::TestClock;

    fn test_registry(cap: usize) -> (Arc<TestClock>, Arc<Registry>) {
        let clock = Arc::new(TestClock::new());
        let reg = Registry::new(clock.clone(), cap);
        (clock, reg)
    }

    #[test]
    fn span_lifecycle_stamps_clock_times() {
        let (clock, reg) = test_registry(8);
        let buf = reg.buffer("t");
        clock.set(100);
        let t = reg.new_trace();
        let sp = reg.begin(t, 0, "outer");
        assert!(sp.is_recording());
        clock.advance(50);
        let id = reg.end(&buf, sp, &[("n", 3)]);
        assert_ne!(id, 0);
        let snap = buf.snapshot();
        assert_eq!(snap.len(), 1);
        assert_eq!(snap[0].name, "outer");
        assert_eq!(snap[0].start_us, 100);
        assert_eq!(snap[0].dur_us, 50);
        assert_eq!(snap[0].trace_id, t);
        assert_eq!(snap[0].args(), &[("n", 3)]);
        assert_eq!(snap[0].tid, buf.tid());
    }

    #[test]
    fn disabled_registry_records_nothing() {
        let (_clock, reg) = test_registry(8);
        reg.set_enabled(false);
        let buf = reg.buffer("t");
        assert_eq!(reg.new_trace(), 0);
        let sp = reg.begin(7, 0, "x");
        assert!(!sp.is_recording());
        assert_eq!(reg.end(&buf, sp, &[]), 0);
        assert_eq!(reg.record(&buf, 7, 0, "y", 1, 2, &[]), 0);
        assert!(buf.is_empty());
        assert_eq!(reg.now_us(), 0);
    }

    #[test]
    fn untraced_requests_are_inert() {
        let (_clock, reg) = test_registry(8);
        let buf = reg.buffer("t");
        let sp = reg.begin(0, 0, "x");
        assert!(!sp.is_recording());
        reg.end(&buf, sp, &[]);
        assert!(buf.is_empty());
    }

    #[test]
    fn ring_overwrites_oldest_beyond_capacity() {
        let (clock, reg) = test_registry(4);
        let buf = reg.buffer("t");
        for i in 0..6u64 {
            clock.set(i * 10);
            reg.record(&buf, 1, 0, "e", i * 10, 1, &[("i", i)]);
        }
        let snap = buf.snapshot();
        assert_eq!(snap.len(), 4, "bounded at capacity");
        // oldest two overwritten; survivors oldest-first
        let starts: Vec<u64> = snap.iter().map(|r| r.start_us).collect();
        assert_eq!(starts, vec![20, 30, 40, 50]);
        assert_eq!(buf.capacity(), 4);
    }

    #[test]
    fn buffers_are_named_and_reused() {
        let (_clock, reg) = test_registry(8);
        let a = reg.buffer("alpha");
        let a2 = reg.buffer("alpha");
        let b = reg.buffer("beta");
        assert!(Arc::ptr_eq(&a, &a2));
        assert_eq!(a.tid(), 1);
        assert_eq!(b.tid(), 2);
        assert_eq!(reg.buffers().len(), 2);
    }

    #[test]
    fn snapshot_merges_rings_in_time_order() {
        let (_clock, reg) = test_registry(8);
        let a = reg.buffer("a");
        let b = reg.buffer("b");
        reg.record(&a, 1, 0, "late", 100, 5, &[]);
        reg.record(&b, 1, 0, "early", 10, 5, &[]);
        reg.record(&a, 2, 0, "mid", 50, 5, &[]);
        let names: Vec<&str> = reg.snapshot().iter().map(|r| r.name).collect();
        assert_eq!(names, vec!["early", "mid", "late"]);
        let last = reg.snapshot_last(2);
        assert_eq!(last.len(), 2);
        assert_eq!(last[0].name, "mid");
    }

    #[test]
    fn args_beyond_capacity_are_dropped_not_reallocated() {
        let (_clock, reg) = test_registry(4);
        let buf = reg.buffer("t");
        let args: Vec<(&'static str, u64)> =
            vec![("a", 1), ("b", 2), ("c", 3), ("d", 4), ("e", 5)];
        reg.record(&buf, 1, 0, "x", 0, 1, &args);
        let snap = buf.snapshot();
        assert_eq!(snap[0].args().len(), MAX_SPAN_ARGS);
        assert_eq!(snap[0].args()[0], ("a", 1));
    }
}
