//! Chrome trace-event export.
//!
//! Converts a slice of [`SpanRecord`]s into the Trace Event Format
//! understood by Perfetto and `chrome://tracing`: a single
//! `{"traceEvents": [...]}` object whose events are complete-duration
//! (`"ph": "X"`) entries. Field mapping:
//!
//! | trace-event field | span field                          |
//! |-------------------|-------------------------------------|
//! | `ph`              | always `"X"` (complete span)        |
//! | `ts` / `dur`      | `start_us` / `dur_us` (microseconds)|
//! | `pid`             | always `1` (single process)         |
//! | `tid`             | buffer ID (`SpanBuf::tid`)          |
//! | `name`            | span name                           |
//! | `args`            | `trace_id`/`span_id`/`parent_id` + the span's logical counters |
//!
//! Output is byte-stable for a given span slice: `Json` objects are
//! BTreeMap-backed and the caller-supplied order (already sorted by
//! `(start_us, span_id)` from `Registry::snapshot`) is preserved.

use crate::obs::span::SpanRecord;
use crate::util::json::{obj, Json};

/// Build the `{"traceEvents": [...]}` document for `spans`.
pub fn chrome_trace_json(spans: &[SpanRecord]) -> Json {
    let events: Vec<Json> = spans.iter().map(event_json).collect();
    obj(vec![("traceEvents", Json::Arr(events))])
}

fn event_json(r: &SpanRecord) -> Json {
    let mut args = vec![
        ("parent_id", Json::Num(r.parent_id as f64)),
        ("span_id", Json::Num(r.span_id as f64)),
        ("trace_id", Json::Num(r.trace_id as f64)),
    ];
    for &(k, v) in r.args() {
        args.push((k, Json::Num(v as f64)));
    }
    obj(vec![
        ("ph", "X".into()),
        ("ts", Json::Num(r.start_us as f64)),
        ("dur", Json::Num(r.dur_us as f64)),
        ("pid", Json::Num(1.0)),
        ("tid", Json::Num(r.tid as f64)),
        ("name", r.name.into()),
        ("args", obj(args)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::Registry;
    use crate::util::clock::TestClock;
    use std::sync::Arc;

    #[test]
    fn chrome_json_is_byte_stable_with_pinned_clock() {
        let build = || {
            let clock = Arc::new(TestClock::new());
            let reg = Registry::new(clock.clone(), 16);
            let buf = reg.buffer("t");
            let t = reg.new_trace();
            clock.set(100);
            let outer = reg.begin(t, 0, "outer");
            clock.set(120);
            let inner = reg.begin(t, outer.span_id, "inner");
            clock.set(150);
            reg.end(&buf, inner, &[("n", 2)]);
            clock.set(200);
            reg.end(&buf, outer, &[]);
            chrome_trace_json(&reg.snapshot()).to_string_compact()
        };
        let a = build();
        let b = build();
        assert_eq!(a, b, "pinned timestamps must give identical bytes");
        assert!(a.starts_with("{\"traceEvents\":["));
        assert!(a.contains("\"ph\":\"X\""));
        assert!(a.contains("\"name\":\"inner\""));
        assert!(a.contains("\"ts\":120"));
        assert!(a.contains("\"dur\":30"));
        assert!(a.contains("\"pid\":1"));
    }

    #[test]
    fn events_carry_causal_ids_in_args() {
        let clock = Arc::new(TestClock::new());
        let reg = Registry::new(clock, 16);
        let buf = reg.buffer("t");
        let parent = reg.record(&buf, 5, 0, "p", 0, 10, &[]);
        reg.record(&buf, 5, parent, "c", 2, 3, &[("fill", 4)]);
        let doc = chrome_trace_json(&reg.snapshot());
        let events = doc.get("traceEvents").as_arr().unwrap();
        assert_eq!(events.len(), 2);
        let args = events[1].get("args");
        assert_eq!(args.get("trace_id").as_u64(), Some(5));
        assert_eq!(args.get("parent_id").as_u64(), Some(parent));
        assert_eq!(args.get("fill").as_u64(), Some(4));
    }
}
