//! End-to-end observability: spans, trace export, histograms, and
//! logical counters.
//!
//! # Span schema
//!
//! A span ([`SpanRecord`]) is a named, timed interval attributed to a
//! request-scoped trace:
//!
//! * `trace_id` — assigned once per request by [`Registry::new_trace`]
//!   at the HTTP/coordinator boundary and carried through
//!   [`TraceCtx`] in the coordinator's `Request` across admission,
//!   dispatch, batching, retries, and cross-worker requeue (a requeued
//!   request keeps its ID).
//! * `span_id` / `parent_id` — process-unique causal links; `parent_id
//!   == 0` marks a trace root. The serving pipeline emits
//!   `http.infer` → `http.parse`, then `pool.admit` (dispatcher),
//!   `pool.queue` → `pool.exec` (worker), with `pool.requeue` /
//!   `pool.retry` instants on the failure paths — four-plus causally
//!   linked spans per served request.
//! * `start_us` / `dur_us` — microseconds on the registry's injected
//!   [`crate::util::clock::Clock`]. This module never reads wall time:
//!   it sits inside the `no-wall-clock-in-pure-paths` lint scope, and
//!   all real clock reads live in `src/util/clock.rs` at the serving
//!   edge.
//! * `args` — up to [`MAX_SPAN_ARGS`] `(name, u64)` pairs of logical
//!   counters (batch fill, attempts, bytes scanned, cache hits).
//!
//! Spans land in bounded per-thread/per-role ring buffers
//! ([`SpanBuf`]): one per long-lived pipeline thread (`dispatch`,
//! `worker-0`, …), one shared `http` ring for the ephemeral connection
//! handlers. Rings overwrite oldest and never allocate after setup, so
//! tracing cost and memory are O(1) per span and bounded overall.
//!
//! # Trace-event field mapping
//!
//! [`chrome_trace_json`] renders spans as Chrome Trace Event Format
//! (loadable in Perfetto / `chrome://tracing`): `ph:"X"`, `ts`/`dur`
//! in microseconds, `pid:1`, `tid` = ring buffer ID, `name` = span
//! name, and `args` carrying `trace_id`/`span_id`/`parent_id` plus the
//! logical counters. Output bytes are stable for a pinned clock. The
//! same document shape is served by `GET /debug/trace?last=N` and
//! written by `rram-accel trace --out results/trace.json`.
//!
//! # Logical-counter convention for pure paths
//!
//! Pure code (`src/sim/`, `src/dse/`, `src/report/`, `src/mapping/`)
//! must stay wall-clock-free, so it is never instrumented with spans
//! directly. Instead it counts *logical* work — points evaluated,
//! cache hits/misses, blocks costed — and the caller at the serving or
//! DSE-runner boundary records those counts into span args (or, for
//! `dse --profile`, wraps each runner stage with timing measured in
//! `main`). Process-wide totals that outlive any one call (store/DSE
//! cache traffic) accumulate in [`counters`] and are exported through
//! `/metrics`.

pub mod chrome;
pub mod hist;
pub mod span;

pub use chrome::chrome_trace_json;
pub use hist::{
    FixedHistogram, Reservoir, BATCH_FILL_BOUNDS, DEFAULT_RESERVOIR_CAP,
    LATENCY_BOUNDS_US,
};
pub use span::{
    ActiveSpan, Registry, SpanBuf, SpanRecord, TraceCtx, DEFAULT_RING_CAPACITY,
    MAX_SPAN_ARGS,
};

/// Process-wide logical counters for work done inside pure paths.
///
/// Pure code cannot read clocks or own an exporter, but atomics are
/// fine: the store and DSE cache bump these on every lookup, and the
/// report layer snapshots them into `/metrics`. Values are
/// monotonically increasing totals since process start.
pub mod counters {
    use std::sync::atomic::{AtomicU64, Ordering};

    static STORE_HITS: AtomicU64 = AtomicU64::new(0);
    static STORE_MISSES: AtomicU64 = AtomicU64::new(0);
    static DSE_CACHE_HITS: AtomicU64 = AtomicU64::new(0);
    static DSE_CACHE_MISSES: AtomicU64 = AtomicU64::new(0);

    /// Snapshot of the logical-counter totals.
    #[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
    pub struct CounterSnapshot {
        pub store_hits: u64,
        pub store_misses: u64,
        pub dse_cache_hits: u64,
        pub dse_cache_misses: u64,
    }

    pub fn store_hit() {
        STORE_HITS.fetch_add(1, Ordering::Relaxed);
    }

    pub fn store_miss() {
        STORE_MISSES.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dse_cache_hit() {
        DSE_CACHE_HITS.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dse_cache_miss() {
        DSE_CACHE_MISSES.fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot() -> CounterSnapshot {
        CounterSnapshot {
            store_hits: STORE_HITS.load(Ordering::Relaxed),
            store_misses: STORE_MISSES.load(Ordering::Relaxed),
            dse_cache_hits: DSE_CACHE_HITS.load(Ordering::Relaxed),
            dse_cache_misses: DSE_CACHE_MISSES.load(Ordering::Relaxed),
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn counters_accumulate_monotonically() {
            let before = snapshot();
            store_hit();
            store_miss();
            dse_cache_hit();
            dse_cache_miss();
            let after = snapshot();
            assert!(after.store_hits >= before.store_hits + 1);
            assert!(after.store_misses >= before.store_misses + 1);
            assert!(after.dse_cache_hits >= before.dse_cache_hits + 1);
            assert!(after.dse_cache_misses >= before.dse_cache_misses + 1);
        }
    }
}
