//! Fixed-bucket histograms and a bounded reservoir.
//!
//! [`FixedHistogram`] replaces the grow-forever latency vector the
//! pool metrics used to carry: memory is O(buckets) regardless of how
//! many observations are recorded, recording is one binary search plus
//! three adds, and merging is element-wise. Bucket bounds are static
//! slices chosen at construction ([`LATENCY_BOUNDS_US`],
//! [`BATCH_FILL_BOUNDS`]) so merged histograms always agree on shape.
//!
//! [`Reservoir`] keeps the first `cap` observations exactly
//! (deterministic — no sampling RNG, per the repo's no-ambient-entropy
//! rule). Tests and small runs get exact quantiles from it; once it
//! saturates, callers fall back to histogram interpolation.

/// Latency bucket upper bounds, microseconds. Log-spaced from 50 µs to
/// 10 s; observations above the last bound land in the overflow
/// bucket.
pub const LATENCY_BOUNDS_US: &[f64] = &[
    50.0,
    100.0,
    250.0,
    500.0,
    1_000.0,
    2_500.0,
    5_000.0,
    10_000.0,
    25_000.0,
    50_000.0,
    100_000.0,
    250_000.0,
    500_000.0,
    1_000_000.0,
    2_500_000.0,
    10_000_000.0,
];

/// Batch-fill bucket upper bounds (requests per batch).
pub const BATCH_FILL_BOUNDS: &[f64] = &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0];

/// Default exact-quantile reservoir capacity.
pub const DEFAULT_RESERVOIR_CAP: usize = 4096;

/// Cumulative-bucket histogram over a static set of upper bounds.
#[derive(Debug, Clone, PartialEq)]
pub struct FixedHistogram {
    bounds: &'static [f64],
    /// Per-bucket (non-cumulative) counts; last slot is overflow.
    counts: Vec<u64>,
    count: u64,
    sum: f64,
    max: f64,
}

impl FixedHistogram {
    pub fn new(bounds: &'static [f64]) -> FixedHistogram {
        FixedHistogram {
            bounds,
            counts: vec![0; bounds.len() + 1],
            count: 0,
            sum: 0.0,
            max: 0.0,
        }
    }

    pub fn record(&mut self, v: f64) {
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.count += 1;
        self.sum += v;
        if v > self.max {
            self.max = v;
        }
    }

    /// Merge another histogram with the same bounds (panics on shape
    /// mismatch — bounds are compile-time constants, so a mismatch is
    /// a programming error, not a data error).
    pub fn merge(&mut self, other: &FixedHistogram) {
        assert_eq!(
            self.bounds.len(),
            other.bounds.len(),
            "histogram bound sets differ"
        );
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        if other.max > self.max {
            self.max = other.max;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Cumulative buckets in Prometheus form: `(le, cumulative_count)`
    /// pairs, final entry `(f64::INFINITY, total)`.
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::with_capacity(self.counts.len());
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            cum += c;
            let le = if i < self.bounds.len() {
                self.bounds[i]
            } else {
                f64::INFINITY
            };
            out.push((le, cum));
        }
        out
    }

    /// Quantile estimate by linear interpolation inside the bucket
    /// containing rank `q * (count - 1)`. Exact enough for p50/p99
    /// reporting once the reservoir has saturated; 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = q.clamp(0.0, 1.0) * (self.count - 1) as f64;
        let mut cum = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let lo_rank = cum as f64;
            cum += c;
            let hi_rank = (cum - 1) as f64;
            if rank <= hi_rank {
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.max
                };
                if hi_rank <= lo_rank {
                    return hi.min(self.max);
                }
                let frac = (rank - lo_rank) / (hi_rank - lo_rank);
                return (lo + frac * (hi - lo)).min(self.max);
            }
        }
        self.max
    }
}

/// Deterministic first-`cap` reservoir: exact values while small,
/// bounded forever.
#[derive(Debug, Clone, PartialEq)]
pub struct Reservoir {
    cap: usize,
    values: Vec<f64>,
    /// Total observations offered, including those not retained.
    seen: u64,
}

impl Reservoir {
    pub fn new(cap: usize) -> Reservoir {
        Reservoir { cap: cap.max(1), values: Vec::new(), seen: 0 }
    }

    pub fn push(&mut self, v: f64) {
        self.seen += 1;
        if self.values.len() < self.cap {
            self.values.push(v);
        }
    }

    /// Merge retained values (bounded by our own cap) and the seen
    /// total.
    pub fn merge(&mut self, other: &Reservoir) {
        self.seen += other.seen;
        for &v in &other.values {
            if self.values.len() >= self.cap {
                break;
            }
            self.values.push(v);
        }
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    pub fn seen(&self) -> u64 {
        self.seen
    }

    /// True while every observation offered is still retained, i.e.
    /// quantiles computed from [`values`](Self::values) are exact.
    pub fn is_exact(&self) -> bool {
        self.seen <= self.values.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_sum_and_buckets() {
        let mut h = FixedHistogram::new(LATENCY_BOUNDS_US);
        h.record(40.0);
        h.record(75.0);
        h.record(75.0);
        h.record(20_000_000.0); // overflow
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 20_000_190.0).abs() < 1e-6);
        assert_eq!(h.max(), 20_000_000.0);
        let b = h.buckets();
        assert_eq!(b.len(), LATENCY_BOUNDS_US.len() + 1);
        assert_eq!(b[0], (50.0, 1));
        assert_eq!(b[1], (100.0, 3));
        let last = b[b.len() - 1];
        assert!(last.0.is_infinite());
        assert_eq!(last.1, 4);
    }

    #[test]
    fn histogram_merge_is_elementwise() {
        let mut a = FixedHistogram::new(BATCH_FILL_BOUNDS);
        let mut b = FixedHistogram::new(BATCH_FILL_BOUNDS);
        a.record(1.0);
        a.record(3.0);
        b.record(3.0);
        b.record(100.0);
        a.merge(&b);
        assert_eq!(a.count(), 4);
        assert_eq!(a.max(), 100.0);
        let buckets = a.buckets();
        assert_eq!(buckets[0], (1.0, 1));
        assert_eq!(buckets[2], (4.0, 3));
        assert_eq!(buckets[buckets.len() - 1].1, 4);
    }

    #[test]
    fn histogram_memory_is_constant() {
        let mut h = FixedHistogram::new(LATENCY_BOUNDS_US);
        for i in 0..100_000u64 {
            h.record((i % 7_000) as f64);
        }
        assert_eq!(h.count(), 100_000);
        assert_eq!(h.counts.len(), LATENCY_BOUNDS_US.len() + 1);
    }

    #[test]
    fn quantile_interpolates_and_clamps() {
        let mut h = FixedHistogram::new(LATENCY_BOUNDS_US);
        assert_eq!(h.quantile(0.5), 0.0);
        for _ in 0..100 {
            h.record(200.0);
        }
        // All mass in the (100, 250] bucket: any quantile lands there.
        let p50 = h.quantile(0.5);
        assert!(p50 > 100.0 && p50 <= 250.0, "p50={p50}");
        assert!(h.quantile(1.0) <= h.max());
        assert!(h.quantile(0.0) >= 0.0);
    }

    #[test]
    fn reservoir_keeps_first_cap_exactly() {
        let mut r = Reservoir::new(3);
        for i in 0..5 {
            r.push(i as f64);
        }
        assert_eq!(r.values(), &[0.0, 1.0, 2.0]);
        assert_eq!(r.seen(), 5);
        assert!(!r.is_exact());
        let mut small = Reservoir::new(8);
        small.push(1.0);
        assert!(small.is_exact());
    }

    #[test]
    fn reservoir_merge_respects_cap() {
        let mut a = Reservoir::new(4);
        a.push(1.0);
        a.push(2.0);
        let mut b = Reservoir::new(4);
        b.push(3.0);
        b.push(4.0);
        b.push(5.0);
        a.merge(&b);
        assert_eq!(a.values(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.seen(), 5);
    }
}
